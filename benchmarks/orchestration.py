"""Orchestration benchmarks — TonY has no tables, so these quantify the
lifecycle claims of §2/§3: submission latency vs job size, RM allocation
throughput, registration->spec barrier cost, fault-recovery overhead, and
the checkpoint/data stall the async critical path removes.

  PYTHONPATH=src python -m benchmarks.orchestration [--smoke] \
      [--json BENCH_orchestration.json]
"""
from __future__ import annotations

import argparse
import json
import statistics
import tempfile
import time

import numpy as np

from repro.checkpoint import AsyncCheckpointer, Checkpointer
from repro.data import PrefetchingLoader, SyntheticLMDataset
from repro.core import (
    ApplicationMaster,
    ContainerRequest,
    EventLog,
    FailureClass,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    NodeHealthTracker,
    Resource,
    RetryPolicy,
    SpeculationPolicy,
    TaskDiagnostics,
    TonYClient,
    YarnLikeBackend,
    job_spec_from_props,
    make_cluster,
)

CHAOS_SEED = 1234


def _noop_program(env, ctx):
    ctx.rendezvous(timeout=30)
    return 0


def _job(workers: int, ps: int = 0):
    props = {
        "tony.application.name": f"bench-{workers}w",
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "512",
        "tony.worker.vcores": "1",
    }
    if ps:
        props.update({"tony.ps.instances": str(ps), "tony.ps.memory": "256",
                      "tony.ps.vcores": "1"})
    return job_spec_from_props(props)


def bench_job_lifecycle_latency() -> list[tuple[str, float, str]]:
    """submit -> SUCCEEDED wall time for growing task counts."""
    rows = []
    for workers in (1, 4, 16, 64):
        rm = make_cluster(num_gpu_nodes=8, num_cpu_nodes=8, gpus_per_node=8,
                          memory_mb=1 << 20, vcores=256)
        client = TonYClient(YarnLikeBackend(rm))
        t0 = time.monotonic()
        res = client.run_and_wait(_job(workers), _noop_program, timeout=120)
        dt = time.monotonic() - t0
        assert res.succeeded
        rows.append((f"lifecycle_{workers}tasks", dt * 1e6,
                     f"tasks={workers}"))
    return rows


def bench_allocation_throughput() -> list[tuple[str, float, str]]:
    rm = make_cluster(num_gpu_nodes=16, num_cpu_nodes=0, gpus_per_node=64,
                      memory_mb=1 << 22, vcores=4096)
    app = rm.submit_application("bench", "default")
    n = 2000
    req = ContainerRequest(Resource(64, 1, 0))
    t0 = time.monotonic()
    cs = [rm.allocate(app, req) for _ in range(n)]
    t_alloc = time.monotonic() - t0
    t0 = time.monotonic()
    for c in cs:
        rm.release(c.container_id)
    t_rel = time.monotonic() - t0
    assert rm.invariants_ok()
    return [("rm_allocate", t_alloc / n * 1e6, f"{n/t_alloc:.0f} alloc/s"),
            ("rm_release", t_rel / n * 1e6, f"{n/t_rel:.0f} release/s")]


def bench_cluster_spec_barrier() -> list[tuple[str, float, str]]:
    """First registration -> cluster_spec_built, from the event log."""
    rows = []
    for workers in (4, 32):
        rm = make_cluster(num_gpu_nodes=8, num_cpu_nodes=8,
                          memory_mb=1 << 20, vcores=256)
        client = TonYClient(YarnLikeBackend(rm))
        res = client.run_and_wait(_job(workers), _noop_program, timeout=120)
        assert res.succeeded
        regs = rm.events.of_kind("task_registered")
        built = rm.events.of_kind("cluster_spec_built")
        dt = built[0].ts - regs[0].ts
        rows.append((f"spec_barrier_{workers}tasks", dt * 1e6,
                     f"registrations={len(regs)}"))
    return rows


def bench_fault_recovery_overhead() -> list[tuple[str, float, str]]:
    """Wall-clock cost of teardown + renegotiation + relaunch (no-ML job)."""
    att = {"n": 0}

    def fail_once(env, ctx):
        ctx.rendezvous(timeout=30)
        if env["TASK_TYPE"] == "worker" and env["TASK_INDEX"] == "0":
            att["n"] += 1
            if att["n"] == 1:
                return 1
        return 0

    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    t0 = time.monotonic()
    res = client.run_and_wait(_job(4), fail_once, timeout=120)
    total = time.monotonic() - t0
    assert res.succeeded and len(res.attempts) == 2
    a1 = res.attempts[0].duration_s
    a2 = res.attempts[1].duration_s
    overhead = total - a2
    return [("fault_recovery_overhead", overhead * 1e6,
             f"attempt1={a1*1e3:.1f}ms attempt2={a2*1e3:.1f}ms")]


def bench_speculation_straggler() -> list[tuple[str, float, str]]:
    """Job-completion time under one injected straggler (seeded SLOW_STEP on
    worker:1), speculation off vs on — the tentpole's headline number."""
    steps, work_s = 12, 0.01

    def gang_program(env, ctx):
        tid = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        speculative = env.get("SPECULATIVE") == "1"
        exec_id = tid + "#1" if speculative else tid
        attempt = int(ctx.shared.get("attempt", 1))
        if not speculative and not ctx.rendezvous(timeout=30):
            return 3
        for step in range(steps):
            if ctx.cancel.is_set():
                return 143
            ctx.step(exec_id, attempt, step)
            time.sleep(work_s)
        return 0

    def run(speculation_on: bool) -> float:
        plan = FaultPlan(seed=CHAOS_SEED).add(
            FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=2,
                      delay_s=0.08))
        ev = EventLog()
        rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev))
        pol = SpeculationPolicy(enabled=speculation_on, slowdown_factor=2.0,
                                patience=3, min_progress=4)
        job = job_spec_from_props({
            "tony.application.name": "bench-straggler",
            "tony.worker.instances": "3",
            "tony.worker.memory": "1024",
            "tony.worker.gpus": "1",
            "tony.worker.node-label": "gpu",
        })
        t0 = time.monotonic()
        res = TonYClient(YarnLikeBackend(rm, speculation=pol)).run_and_wait(
            job, gang_program, timeout=120)
        dt = time.monotonic() - t0
        assert res.succeeded and len(res.attempts) == 1
        if speculation_on:
            assert res.attempts[0].speculation == {"worker:1": "won"}
        return dt

    t_off = run(False)
    t_on = run(True)
    assert t_on < t_off, \
        f"speculation should cut straggler JCT: on={t_on:.2f}s off={t_off:.2f}s"
    return [("straggler_no_spec", t_off * 1e6, "worker:1 slowed 80ms/step"),
            ("straggler_with_spec", t_on * 1e6,
             f"backup wins; speedup={t_off / t_on:.2f}x")]


def bench_elastic_resize() -> list[tuple[str, float, str]]:
    """Degraded throughput vs. failed-job JCT: a 4-worker job on a cluster
    with only 3 usable slots. Rigid gangs burn the negotiation window and
    every retry; an elastic (min-instances=2) gang downsizes to 3 and
    finishes — wasted wall-clock vs. useful degraded work."""
    steps, work_s = 8, 0.005

    def gang_program(env, ctx):
        tid = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        attempt = int(ctx.shared.get("attempt", 1))
        if not ctx.rendezvous(timeout=30, exec_id=tid, attempt=attempt):
            return 3
        if tid == "worker:0":
            try:
                for step in range(steps):
                    if ctx.cancel.is_set():
                        return 143
                    ctx.step(tid, attempt, step)
                    time.sleep(work_s)
            finally:
                ctx.shared["done"] = True
        else:
            while not ctx.cancel.is_set() and not ctx.shared.get("done"):
                time.sleep(0.002)
        ctx.rendezvous(timeout=5, exec_id=tid, attempt=attempt)
        return 0

    def run(elastic: bool) -> tuple[float, bool]:
        ev = EventLog()
        health = NodeHealthTracker(threshold=1, parole_s=3600.0, events=ev)
        rm = make_cluster(num_gpu_nodes=4, num_cpu_nodes=0, gpus_per_node=1,
                          memory_mb=2048, vcores=4, event_log=ev,
                          health=health)
        health.record_failure("gpu-node-0", TaskDiagnostics(
            task_id="worker:0", exit_status=137,
            classification=FailureClass.INFRA, message="pre-struck"))
        props = {
            "tony.application.name": "bench-elastic",
            "tony.application.max-attempts": "2",
            "tony.worker.instances": "4",
            "tony.worker.memory": "1024",
            "tony.worker.gpus": "1",
            "tony.worker.node-label": "gpu",
        }
        if elastic:
            props["tony.worker.min-instances"] = "2"
        job = job_spec_from_props(props)
        app_id = rm.submit_application(job.name, job.queue)
        am = ApplicationMaster(
            rm, app_id, job, gang_program,
            retry_policy=RetryPolicy(max_attempts=2).with_clock(lambda s: None))
        am.NEGOTIATION_TIMEOUT_S = 0.4
        t0 = time.monotonic()
        res = am.run()
        dt = time.monotonic() - t0
        assert not rm.live_containers() and rm.invariants_ok()
        if elastic:
            assert res.succeeded and res.resized_attempts == {1: {"worker": 3}}
        else:
            assert not res.succeeded   # rigid gang can never fit
        return dt, res.succeeded

    t_rigid, _ = run(False)
    t_elastic, _ = run(True)
    return [("elastic_rigid_fails", t_rigid * 1e6,
             "4-worker rigid gang on 3 slots: all wall-clock wasted"),
            ("elastic_degraded_completes", t_elastic * 1e6,
             "min-instances=2 downsizes to 3 and finishes")]


def _busy_wait(seconds: float) -> None:
    """Simulated accelerator step: occupy the wall clock without yielding so
    long that timing noise dominates (sleep granularity is fine here — the
    background writer/producer threads get plenty of air either way)."""
    time.sleep(seconds)


def bench_checkpoint_stall(smoke: bool = False) -> list[tuple[str, float, str]]:
    """Per-checkpoint step-time spike, sync vs async writer: the step that
    lands on a checkpoint boundary pays the whole npz write on the sync path
    and only the host snapshot + hand-off on the async path. The headline
    acceptance number: async must cut the spike >= 2x."""
    steps, ckpt_every = (18, 6) if smoke else (30, 6)
    work_s = 0.01
    # ~4 MB state: big enough that the blocking write dwarfs timer noise,
    # small enough that the write fits inside the ckpt_every window (no
    # steady-state backpressure on the async path)
    tree = {f"w{i}": np.full((256, 1024), float(i), np.float32)
            for i in range(4)}

    def run(use_async: bool) -> tuple[float, float]:
        d = tempfile.mkdtemp(prefix="bench-ckpt-")
        ckpt = AsyncCheckpointer(d) if use_async else Checkpointer(d)
        ckpt_times, plain_times = [], []
        try:
            for step in range(steps):
                t0 = time.monotonic()
                _busy_wait(work_s)
                is_ckpt = (step + 1) % ckpt_every == 0
                if is_ckpt:
                    ckpt.save(tree, step + 1)
                (ckpt_times if is_ckpt else plain_times).append(
                    time.monotonic() - t0)
        finally:
            if use_async:
                ckpt.flush()
                ckpt.close()
        baseline = statistics.median(plain_times)
        spike = max(0.0, statistics.mean(ckpt_times) - baseline)
        return spike, baseline

    spike_sync, base_sync = run(False)
    spike_async, base_async = run(True)
    ratio = spike_sync / max(spike_async, 1e-6)
    assert ratio >= 2.0, (
        f"async checkpointing must cut the per-checkpoint spike >= 2x: "
        f"sync={spike_sync*1e3:.2f}ms async={spike_async*1e3:.2f}ms")
    return [
        ("ckpt_stall_sync", spike_sync * 1e6,
         f"blocking npz write on the step; baseline={base_sync*1e3:.1f}ms"),
        ("ckpt_stall_async", spike_async * 1e6,
         f"snapshot+handoff only; spike cut {ratio:.1f}x"),
    ]


def bench_train_stall_breakdown(smoke: bool = False) -> list[tuple[str, float, str]]:
    """Steady-state step-time breakdown over the four (data, ckpt) pipeline
    combinations: how much of each step is batch construction vs checkpoint
    write vs actual compute. The async+prefetch cell is the shipped default."""
    steps, ckpt_every = (24, 8) if smoke else (48, 8)
    work_s = 0.004
    B, T = (64, 256) if smoke else (128, 512)
    tree = {"w": np.full((256, 1024), 1.0, np.float32)}   # 1 MB state

    def run(prefetch: bool, use_async: bool) -> float:
        data = SyntheticLMDataset(B, T, vocab_size=8192, seed=0)
        if prefetch:
            data = PrefetchingLoader(data, depth=2)
        d = tempfile.mkdtemp(prefix="bench-stall-")
        ckpt = AsyncCheckpointer(d) if use_async else Checkpointer(d)
        t0 = time.monotonic()
        try:
            for step in range(steps):
                data.next_batch()
                _busy_wait(work_s)
                if (step + 1) % ckpt_every == 0:
                    ckpt.save(tree, step + 1)
        finally:
            if use_async:
                ckpt.flush()
                ckpt.close()
            if prefetch:
                data.close()
        return (time.monotonic() - t0) / steps

    rows = []
    for prefetch, use_async, label in [
            (False, False, "sync_data_sync_ckpt"),
            (True, False, "prefetch_data_sync_ckpt"),
            (False, True, "sync_data_async_ckpt"),
            (True, True, "prefetch_data_async_ckpt")]:
        dt = run(prefetch, use_async)
        rows.append((f"train_stall_{label}", dt * 1e6,
                     f"mean step over {steps} steps, ckpt every {ckpt_every}"))
    return rows


def all_benches(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    rows += bench_allocation_throughput()
    rows += bench_job_lifecycle_latency()
    rows += bench_cluster_spec_barrier()
    rows += bench_fault_recovery_overhead()
    rows += bench_speculation_straggler()
    rows += bench_elastic_resize()
    rows += bench_checkpoint_stall(smoke=smoke)
    rows += bench_train_stall_breakdown(smoke=smoke)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale for the CI bench-smoke job")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows as a JSON benchmark artifact")
    args = ap.parse_args()
    rows = all_benches(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suite": "orchestration", "smoke": args.smoke,
                       "rows": [{"name": n, "us_per_call": round(us, 1),
                                 "derived": d} for n, us, d in rows]},
                      f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
