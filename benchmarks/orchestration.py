"""Orchestration benchmarks — TonY has no tables, so these quantify the
lifecycle claims of §2/§3: submission latency vs job size, RM allocation
throughput, registration->spec barrier cost, and fault-recovery overhead."""
from __future__ import annotations

import time

from repro.core import (
    ContainerRequest,
    EventLog,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    Resource,
    SpeculationPolicy,
    TonYClient,
    YarnLikeBackend,
    job_spec_from_props,
    make_cluster,
)

CHAOS_SEED = 1234


def _noop_program(env, ctx):
    ctx.rendezvous(timeout=30)
    return 0


def _job(workers: int, ps: int = 0):
    props = {
        "tony.application.name": f"bench-{workers}w",
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "512",
        "tony.worker.vcores": "1",
    }
    if ps:
        props.update({"tony.ps.instances": str(ps), "tony.ps.memory": "256",
                      "tony.ps.vcores": "1"})
    return job_spec_from_props(props)


def bench_job_lifecycle_latency() -> list[tuple[str, float, str]]:
    """submit -> SUCCEEDED wall time for growing task counts."""
    rows = []
    for workers in (1, 4, 16, 64):
        rm = make_cluster(num_gpu_nodes=8, num_cpu_nodes=8, gpus_per_node=8,
                          memory_mb=1 << 20, vcores=256)
        client = TonYClient(YarnLikeBackend(rm))
        t0 = time.monotonic()
        res = client.run_and_wait(_job(workers), _noop_program, timeout=120)
        dt = time.monotonic() - t0
        assert res.succeeded
        rows.append((f"lifecycle_{workers}tasks", dt * 1e6,
                     f"tasks={workers}"))
    return rows


def bench_allocation_throughput() -> list[tuple[str, float, str]]:
    rm = make_cluster(num_gpu_nodes=16, num_cpu_nodes=0, gpus_per_node=64,
                      memory_mb=1 << 22, vcores=4096)
    app = rm.submit_application("bench", "default")
    n = 2000
    req = ContainerRequest(Resource(64, 1, 0))
    t0 = time.monotonic()
    cs = [rm.allocate(app, req) for _ in range(n)]
    t_alloc = time.monotonic() - t0
    t0 = time.monotonic()
    for c in cs:
        rm.release(c.container_id)
    t_rel = time.monotonic() - t0
    assert rm.invariants_ok()
    return [("rm_allocate", t_alloc / n * 1e6, f"{n/t_alloc:.0f} alloc/s"),
            ("rm_release", t_rel / n * 1e6, f"{n/t_rel:.0f} release/s")]


def bench_cluster_spec_barrier() -> list[tuple[str, float, str]]:
    """First registration -> cluster_spec_built, from the event log."""
    rows = []
    for workers in (4, 32):
        rm = make_cluster(num_gpu_nodes=8, num_cpu_nodes=8,
                          memory_mb=1 << 20, vcores=256)
        client = TonYClient(YarnLikeBackend(rm))
        res = client.run_and_wait(_job(workers), _noop_program, timeout=120)
        assert res.succeeded
        regs = rm.events.of_kind("task_registered")
        built = rm.events.of_kind("cluster_spec_built")
        dt = built[0].ts - regs[0].ts
        rows.append((f"spec_barrier_{workers}tasks", dt * 1e6,
                     f"registrations={len(regs)}"))
    return rows


def bench_fault_recovery_overhead() -> list[tuple[str, float, str]]:
    """Wall-clock cost of teardown + renegotiation + relaunch (no-ML job)."""
    att = {"n": 0}

    def fail_once(env, ctx):
        ctx.rendezvous(timeout=30)
        if env["TASK_TYPE"] == "worker" and env["TASK_INDEX"] == "0":
            att["n"] += 1
            if att["n"] == 1:
                return 1
        return 0

    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    t0 = time.monotonic()
    res = client.run_and_wait(_job(4), fail_once, timeout=120)
    total = time.monotonic() - t0
    assert res.succeeded and len(res.attempts) == 2
    a1 = res.attempts[0].duration_s
    a2 = res.attempts[1].duration_s
    overhead = total - a2
    return [("fault_recovery_overhead", overhead * 1e6,
             f"attempt1={a1*1e3:.1f}ms attempt2={a2*1e3:.1f}ms")]


def bench_speculation_straggler() -> list[tuple[str, float, str]]:
    """Job-completion time under one injected straggler (seeded SLOW_STEP on
    worker:1), speculation off vs on — the tentpole's headline number."""
    steps, work_s = 12, 0.01

    def gang_program(env, ctx):
        tid = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        speculative = env.get("SPECULATIVE") == "1"
        exec_id = tid + "#1" if speculative else tid
        attempt = int(ctx.shared.get("attempt", 1))
        if not speculative and not ctx.rendezvous(timeout=30):
            return 3
        for step in range(steps):
            if ctx.cancel.is_set():
                return 143
            ctx.step(exec_id, attempt, step)
            time.sleep(work_s)
        return 0

    def run(speculation_on: bool) -> float:
        plan = FaultPlan(seed=CHAOS_SEED).add(
            FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=2,
                      delay_s=0.08))
        ev = EventLog()
        rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev))
        pol = SpeculationPolicy(enabled=speculation_on, slowdown_factor=2.0,
                                patience=3, min_progress=4)
        job = job_spec_from_props({
            "tony.application.name": "bench-straggler",
            "tony.worker.instances": "3",
            "tony.worker.memory": "1024",
            "tony.worker.gpus": "1",
            "tony.worker.node-label": "gpu",
        })
        t0 = time.monotonic()
        res = TonYClient(YarnLikeBackend(rm, speculation=pol)).run_and_wait(
            job, gang_program, timeout=120)
        dt = time.monotonic() - t0
        assert res.succeeded and len(res.attempts) == 1
        if speculation_on:
            assert res.attempts[0].speculation == {"worker:1": "won"}
        return dt

    t_off = run(False)
    t_on = run(True)
    assert t_on < t_off, \
        f"speculation should cut straggler JCT: on={t_on:.2f}s off={t_off:.2f}s"
    return [("straggler_no_spec", t_off * 1e6, "worker:1 slowed 80ms/step"),
            ("straggler_with_spec", t_on * 1e6,
             f"backup wins; speedup={t_off / t_on:.2f}x")]


def all_benches() -> list[tuple[str, float, str]]:
    rows = []
    rows += bench_allocation_throughput()
    rows += bench_job_lifecycle_latency()
    rows += bench_cluster_spec_barrier()
    rows += bench_fault_recovery_overhead()
    rows += bench_speculation_straggler()
    return rows
