"""Training / serving throughput micro-benchmarks (CPU smoke scale) — the ML
side of the jobs TonY orchestrates.

  PYTHONPATH=src python -m benchmarks.training [--json BENCH_training.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMDataset
from repro.distributed.steps import init_train_state, make_train_fn
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.models import model as M


def bench_train_step() -> list[tuple[str, float, str]]:
    cfg = get_config("tony-paper-mlp")
    B, T = 8, 128
    mesh = make_local_mesh()
    data = SyntheticLMDataset(B, T, cfg.vocab_size)
    with set_mesh(mesh):
        fn, _ = make_train_fn(cfg, mesh, "fsdp_tp",
                              shape=ShapeConfig("b", T, B, "train"))
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        state, _ = fn(state, batch)  # compile
        jax.block_until_ready(state["params"])
        n = 5
        t0 = time.monotonic()
        for _ in range(n):
            state, m = fn(state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.monotonic() - t0) / n
    return [("train_step_paper_mlp", dt * 1e6,
             f"{B*T/dt:.0f} tok/s params={cfg.param_count()/1e6:.1f}M")]


def bench_decode_step() -> list[tuple[str, float, str]]:
    cfg = get_smoke_config("qwen3-1.7b")
    B, C = 4, 64
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = M.init_decode_state(cfg, params, B, C)
    step = jax.jit(lambda p, s, t: M.decode_step(cfg, p, s, t, C))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, state = step(params, state, tok)  # compile
    jax.block_until_ready(logits)
    n = 20
    t0 = time.monotonic()
    for _ in range(n):
        logits, state = step(params, state, tok)
    jax.block_until_ready(logits)
    dt = (time.monotonic() - t0) / n
    return [("decode_step_qwen3_smoke", dt * 1e6, f"{B/dt:.0f} tok/s")]


def bench_kernels() -> list[tuple[str, float, str]]:
    from repro.kernels import ops, ref
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    for name, fn in [("flash_attention_interp",
                      lambda: ops.flash_attention(q, k, v, causal=True)),
                     ("attention_ref",
                      lambda: ref.flash_attention_ref(q, k, v, causal=True))]:
        fn()  # compile
        t0 = time.monotonic()
        for _ in range(3):
            out = fn()
        jax.block_until_ready(out)
        rows.append((name, (time.monotonic() - t0) / 3 * 1e6,
                     "interpret-mode (correctness path)"))
    return rows


def all_benches() -> list[tuple[str, float, str]]:
    return bench_train_step() + bench_decode_step() + bench_kernels()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for CI symmetry (these benches already "
                         "run at smoke scale)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows as a JSON benchmark artifact")
    args = ap.parse_args()
    rows = all_benches()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suite": "training", "smoke": args.smoke,
                       "rows": [{"name": n, "us_per_call": round(us, 1),
                                 "derived": d} for n, us, d in rows]},
                      f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
