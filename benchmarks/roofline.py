"""Roofline derivation from dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun_all) and
derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

cost_analysis() on a GSPMD-partitioned module reports PER-CHIP numbers (we
verified: per-layer marginal flops match analytic_per_layer/n_chips), so the
terms above are already per-chip; MODEL_FLOPS ratio uses flops * n_chips.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

MESH_CHIPS = {"16x16": 256, "2x16x16": 512}


def load_results(directory: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def derive_terms(rec: dict) -> dict | None:
    """Roofline terms from one analysis-mode record."""
    ex = rec.get("extrapolated")
    if not rec.get("ok") or ex is None:
        return None
    chips = MESH_CHIPS[rec["mesh"]]
    flops = ex["flops"]
    byts = ex["bytes_accessed"]
    coll = ex["collective_bytes_total"]
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    model_fl = rec["model_flops"]
    ratio = model_fl / (flops * chips) if flops else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "strategy": rec.get("strategy", "fsdp_tp"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_fl,
        "hlo_flops_per_chip": flops,
        "useful_ratio": ratio,
        "roofline_bound_s": max(t_compute, t_memory, t_coll),
        "collectives": ex["collectives"],
    }


def summarize(directory: str) -> tuple[list[dict], list[dict]]:
    """Returns (analysis_terms, compile_records)."""
    terms, compiles = [], []
    for rec in load_results(directory):
        if rec.get("mode") == "analysis":
            t = derive_terms(rec)
            if t:
                terms.append(t)
            elif rec.get("skipped"):
                terms.append({"arch": rec["arch"], "shape": rec["shape"],
                              "mesh": rec["mesh"], "skipped": rec["skipped"]})
        elif rec.get("mode") == "compile":
            compiles.append(rec)
    return terms, compiles


def markdown_table(terms: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful FLOP ratio |\n|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for t in terms:
        if "skipped" in t:
            rows.append(f"| {t['arch']} | {t['shape']} | {t['mesh']} | — | — | — "
                        f"| SKIPPED | — |")
            continue
        rows.append(
            f"| {t['arch']} | {t['shape']} | {t['mesh']} "
            f"| {t['t_compute_s']:.3e} | {t['t_memory_s']:.3e} "
            f"| {t['t_collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['useful_ratio']:.2f} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    terms, compiles = summarize(args.dir)
    if args.json:
        print(json.dumps(terms, indent=2))
        return
    ok = sum(1 for c in compiles if c.get("ok"))
    print(f"compile records: {ok}/{len(compiles)} ok")
    print(markdown_table(terms))


if __name__ == "__main__":
    main()
