"""Assemble EXPERIMENTS.md from the dry-run artifacts, the measured-benchmark
JSON artifacts (``BENCH_*.json`` from ``benchmarks/orchestration.py`` /
``benchmarks/training.py`` — the CI bench-smoke job's trajectory), and the
hand-written perf ledger (experiments/perf_ledger.md).

  PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun] \
      [--bench BENCH_orchestration.json BENCH_training.json]
"""
from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict

from benchmarks.roofline import MESH_CHIPS, markdown_table, summarize

HEADER = """# EXPERIMENTS — TonY reproduction

All numbers derive from compiled artifacts of the multi-pod dry-run
(`repro.launch.dryrun`): this container is CPU-only, so TPU v5e is the
*target* (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI) and every term
below is computed from `memory_analysis()` / `cost_analysis()` /
collective-ops parsed out of the optimized HLO. Orchestration and
training-correctness results run for real on CPU (see `benchmarks/run.py`
and `tests/`).

Methodology notes:
- `cost_analysis()` on a GSPMD-partitioned module reports **per-chip**
  FLOPs/bytes (verified against analytic per-layer FLOPs), so roofline terms
  are per-chip; MODEL_FLOPS ratios multiply back by chip count.
- XLA counts while-loop bodies **once**, so the scanned-layer production
  program under-reports; the `analysis` dry-run mode therefore lowers
  UNROLLED 1x- and 2x-pattern variants on the same mesh and extrapolates
  exact per-layer marginals (whole-model exact when depth <= 12). RWKV's
  time-scan body is corrected analytically (`rwkv_correction_flops`).
- `bytes accessed` is XLA's post-fusion operand+output traffic — an upper
  bound on HBM traffic (CPU fusion is weaker than TPU), used as a
  *comparable* metric across variants, not an absolute prediction.
"""


def dryrun_section(compiles: list[dict]) -> str:
    rows = ["## §Dry-run — compile proof, memory, collectives",
            "",
            "Every (architecture x input-shape) lowers AND compiles for the"
            " production meshes: 16x16 = 256 chips (single pod) and"
            " 2x16x16 = 512 chips (multi-pod, 'pod' axis over DCN).",
            "",
            "| arch | shape | mesh | status | args GB/chip | temp GB/chip |"
            " collective ops | AG GB | AR GB | A2A GB |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    ok = fail = skip = 0
    for rec in sorted(compiles, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if rec.get("skipped"):
            skip += 1
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                        f"| SKIP ({rec['skipped'][:40]}…) | — | — | — | — | — | — |")
            continue
        if not rec.get("ok"):
            fail += 1
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
                        f"| **FAIL** {rec.get('error','')[:60]} | — | — | — | — | — | — |")
            continue
        ok += 1
        f = rec["full"]
        m = f["memory"] or {}
        c = f["collectives"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok "
            f"| {m.get('argument_bytes_per_device', 0)/1e9:.2f} "
            f"| {m.get('temp_bytes_per_device', 0)/1e9:.2f} "
            f"| {int(c['count'])} "
            f"| {c['all-gather']/1e9:.2f} | {c['all-reduce']/1e9:.2f} "
            f"| {c['all-to-all']/1e9:.2f} |")
    rows.insert(1, f"\n**{ok} ok / {fail} failed / {skip} skipped**\n")
    return "\n".join(rows)


def roofline_section(terms: list[dict]) -> str:
    out = ["## §Roofline — per-chip terms from the single-pod dry-run", "",
           markdown_table([t for t in terms if t.get("mesh", "16x16") == "16x16"]),
           "", "### Dominant-term counts"]
    counts = defaultdict(int)
    for t in terms:
        counts[t.get("dominant", "skipped")] += 1
    for k, v in sorted(counts.items()):
        out.append(f"- {k}: {v}")
    out += ["", "### What would move each dominant term down", ""]
    byarch = {}
    for t in terms:
        if "dominant" in t:
            byarch.setdefault((t["arch"], t["shape"]), t)
    for (arch, shape), t in sorted(byarch.items()):
        hint = {
            "memory": "cut materialized O(T^2)/logits f32 buffers "
                      "(fused softmax, flash kernel on real TPU, bf16 scores)",
            "compute": "reduce remat recompute; larger per-chip tiles",
            "collective": "change strategy (tp_only kills FSDP gathers; "
                          "reduce-scatter grads), tune MoE group size",
        }[t["dominant"]]
        out.append(f"- {arch} x {shape}: {t['dominant']}-bound -> {hint}")
    return "\n".join(out)


def bench_section(paths: list[str]) -> str:
    """Render measured-benchmark JSON artifacts (CI bench-smoke trajectory)."""
    rows = ["## §Benchmarks — measured (CPU, smoke scale)", "",
            "| suite | name | us/call | derived |", "|---|---|---|---|"]
    n = 0
    for path in paths:
        if not os.path.exists(path):
            rows.append(f"| — | ({os.path.basename(path)} missing) | — | — |")
            continue
        with open(path) as f:
            art = json.load(f)
        for r in art.get("rows", []):
            n += 1
            rows.append(f"| {art.get('suite', '?')} | {r['name']} "
                        f"| {r['us_per_call']:.1f} | {r['derived']} |")
    rows.insert(1, f"\n**{n} measured rows** — the per-PR baseline the "
                   "perf acceptance criteria diff against.\n")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    ap.add_argument("--perf-ledger", default="experiments/perf_ledger.md")
    ap.add_argument("--bench", nargs="*", default=[],
                    help="BENCH_*.json artifacts to fold into the report")
    args = ap.parse_args()
    if os.path.isdir(args.dir):
        terms, compiles = summarize(args.dir)
    else:
        terms, compiles = [], []     # bench-smoke runs without dry-run output
    parts = [HEADER]
    if compiles:
        parts += [dryrun_section(compiles), "", roofline_section(terms), ""]
    if args.bench:
        parts += [bench_section(args.bench), ""]
    if os.path.exists(args.perf_ledger):
        parts.append(open(args.perf_ledger).read())
    else:
        parts.append("## §Perf\n\n(perf ledger pending)")
    with open(args.out, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {args.out}: {len(compiles)} compile records, "
          f"{len(terms)} roofline rows, {len(args.bench)} bench artifacts")


if __name__ == "__main__":
    main()
