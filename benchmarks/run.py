# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# TonY (OpML'19) has no result tables — its claims are lifecycle behaviours —
# so the benchmark suite quantifies each claimed behaviour (§2/§3) plus the
# training/serving substrate and the roofline summary from the dry-runs.
from __future__ import annotations

import os
import sys


def main() -> None:
    rows: list[tuple[str, float, str]] = []
    from benchmarks import orchestration, training
    rows += orchestration.all_benches()
    rows += training.all_benches()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # roofline summary (if the dry-run matrix has been produced)
    dr = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
    if os.path.isdir(dr):
        from benchmarks.roofline import summarize
        terms, compiles = summarize(dr)
        ok = sum(1 for c in compiles if c.get("ok"))
        print(f"dryrun_compile_ok,{float(ok)},{ok}/{len(compiles)} records")
        done = [t for t in terms if "skipped" not in t]
        print(f"roofline_records,{float(len(done))},see EXPERIMENTS.md §Roofline")


if __name__ == "__main__":
    main()
