"""Pure-jnp oracles for every Pallas kernel (independent implementations —
deliberately the naive O(T^2)/sequential forms)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,T,H,hd); k,v: (B,S,KV,hd); GQA by head grouping. f32 softmax."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, T, KV, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("btkgh,bskh->bkgts", qf, kf) / jnp.sqrt(hd)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, vf)
    return out.reshape(B, T, H, hd).astype(q.dtype)


def linear_scan_ref(a, b, h0=None):
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t.
    a, b: (B, T, C) -> h: (B, T, C), computed sequentially in f32."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    B, T, C = a.shape
    h = jnp.zeros((B, C), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h, (af.transpose(1, 0, 2), bf.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(a.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


def wkv_ref(r, k, v, log_w, u):
    """RWKV-6 WKV. r,k,v,log_w: (B,T,H,K); u: (H,K) -> (B,T,H,K), f32 state."""
    B, T, H, K = r.shape
    S0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(S, ins):
        rt, kt, vt, lwt = ins
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., :, None] * kv)
        S = jnp.exp(lwt)[..., :, None] * S + kv
        return S, y

    tr = lambda x: x.astype(jnp.float32).transpose(1, 0, 2, 3)  # noqa: E731
    _, ys = jax.lax.scan(step, S0, (tr(r), tr(k), tr(v), tr(log_w)))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype)
