"""RWKV-6 WKV Pallas kernel: matrix-valued per-head state with
data-dependent per-channel decay.

    S_t = diag(exp(log_w_t)) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

TPU adaptation: one (K x K) f32 state tile per (batch, head) lives in VMEM
scratch and persists across the sequential time-chunk grid dimension; the
rank-1 update k^T v and the r-contraction both map onto the MXU as (K x K)
outer/inner products. K = 64 for the assigned rwkv6-3b (pad to 128 lanes on
real hardware; interpret mode is exact).

Grid: (B, H, num_time_chunks), time innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 64


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
                block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, :, 0, :].astype(jnp.float32)     # (bt, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)           # (K,)

    def body(i, S):
        kv = k[i][:, None] * v[i][None, :]        # (K, K) rank-1
        y = (r[i][:, None] * (S + u[:, None] * kv)).sum(axis=0)
        o_ref[0, pl.dslice(i, 1), 0, :] = y[None].astype(o_ref.dtype)
        return jnp.exp(lw[i])[:, None] * S + kv

    S = jax.lax.fori_loop(0, block_t, body, s_scr[...])
    s_scr[...] = S


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv(r, k, v, log_w, u, *, block_t: int = DEFAULT_BLOCK_T,
        interpret: bool = True):
    """r,k,v,log_w: (B,T,H,K); u: (H,K) -> y: (B,T,H,K)."""
    B, T, H, K = r.shape
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)
    kernel = functools.partial(_wkv_kernel, block_t=bt)
    return pl.pallas_call(
        kernel,
        grid=(B, H, T // bt),
        in_specs=[
            pl.BlockSpec((1, bt, 1, K), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, K), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, K), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, bt, 1, K), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, K), lambda b, h, t: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, 1, K), lambda b, h, t: (b, t, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, K), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)
