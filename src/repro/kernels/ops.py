"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; the kernel bodies
execute in Python for correctness validation). On real TPU set
``REPRO_PALLAS_INTERPRET=0``.
"""
from __future__ import annotations

import os

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.linear_scan import linear_scan as _linear_scan
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.wkv import wkv as _wkv

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=INTERPRET)


def linear_scan(a, b, *, block_t=128, block_c=128):
    return _linear_scan(a, b, block_t=block_t, block_c=block_c,
                        interpret=INTERPRET)


def wkv(r, k, v, log_w, u, *, block_t=64):
    return _wkv(r, k, v, log_w, u, block_t=block_t, interpret=INTERPRET)


def rmsnorm(x, scale, *, eps=1e-6, block_rows=128):
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=INTERPRET)
