"""Chunked diagonal linear recurrence Pallas kernel: h_t = a_t*h_{t-1} + b_t.

Serves RG-LRU (and any diagonal SSM). TPU adaptation: time is chunked along
the sequential innermost grid dimension; the carry h lives in VMEM scratch and
flows across chunks, so HBM traffic is exactly one read of (a, b) and one
write of h — the memory-bound roofline for this op. Channels tile the lane
dimension (128-aligned).

Grid: (B, num_channel_tiles, num_time_chunks), time innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_C = 128


def _scan_kernel(a_ref, b_ref, o_ref, h_scr, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)   # (bt, bc)
    b = b_ref[0].astype(jnp.float32)

    def body(i, h):
        h = a[i] * h + b[i]
        o_ref[0, pl.dslice(i, 1), :] = h[None].astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, body, h_scr[0])
    h_scr[0, :] = h


@functools.partial(jax.jit, static_argnames=("block_t", "block_c", "interpret"))
def linear_scan(a, b, *, block_t: int = DEFAULT_BLOCK_T,
                block_c: int = DEFAULT_BLOCK_C, interpret: bool = True):
    """a, b: (B, T, C) -> h: (B, T, C)."""
    B, T, C = a.shape
    bt = min(block_t, T)
    bc = min(block_c, C)
    assert T % bt == 0 and C % bc == 0, (T, bt, C, bc)
    kernel = functools.partial(_scan_kernel, block_t=bt)
    return pl.pallas_call(
        kernel,
        grid=(B, C // bc, T // bt),
        in_specs=[
            pl.BlockSpec((1, bt, bc), lambda bb, cc, tt: (bb, tt, cc)),
            pl.BlockSpec((1, bt, bc), lambda bb, cc, tt: (bb, tt, cc)),
        ],
        out_specs=pl.BlockSpec((1, bt, bc), lambda bb, cc, tt: (bb, tt, cc)),
        out_shape=jax.ShapeDtypeStruct((B, T, C), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        interpret=interpret,
    )(a, b)
