"""Fused RMSNorm Pallas kernel (reduce + rsqrt + scale in one VMEM pass)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + s_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6,
            block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """x: (N, d); scale: (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    N = x2.shape[0]
    br = min(block_rows, N)
    assert N % br == 0, (N, br)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(N // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
