"""Flash attention Pallas TPU kernel: online-softmax tiling with GQA, causal
and sliding-window masking.

TPU adaptation (DESIGN.md §6): the GPU algorithm's warp-level softmax turns
into MXU-aligned (block_q x block_k) tiles streamed HBM->VMEM; the running
(m, l, acc) state lives in VMEM scratch and persists across the sequential
innermost grid dimension (TPU grids iterate in order, which replaces the GPU
thread-block loop).

Grid: (B, KV_heads, num_q_blocks, num_k_blocks), k innermost.
Blocks: q (1, bq, 1, G, hd) | k,v (1, bk, 1, hd) | o (1, bq, 1, G, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :, :].astype(jnp.float32)     # (bq, G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # (bk, hd)

    s = jnp.einsum("qgh,kh->qgk", q, k) * scale      # (bq, G, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1, 1), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_k), 2)
    mask = jnp.ones((block_q, 1, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # (bq, G)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked running max
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * alpha[..., None] + jnp.einsum("qgk,kh->qgh", p, v)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        out = acc_scr[...] / safe_l[..., None]
        out = jnp.where((l == 0.0)[..., None], 0.0, out)
        o_ref[0, :, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q: (B,T,H,hd); k,v: (B,S,KV,hd) -> (B,T,H,hd).

    Differentiable: custom_vjp with the Pallas kernel forward and the exact
    reference-math backward (Pallas interpret mode has no JVP rule; on real
    TPU the backward would be a second kernel with the same tiling)."""
    return _flash_vjp(q, k, v, causal, window, block_q, block_k, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, window, block_q, block_k, interpret):
    return _flash_impl(q, k, v, causal=causal, window=window, block_q=block_q,
                       block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out = _flash_impl(q, k, v, causal=causal, window=window, block_q=block_q,
                      block_k=block_k, interpret=interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, g):
    from repro.kernels.ref import flash_attention_ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal,
                                               window=window), q, k, v)
    return vjp(g)


_flash_vjp.defvjp(_flash_fwd, _flash_bwd)


def _flash_impl(q, k, v, *, causal: bool, window: int, block_q: int,
                block_k: int, interpret: bool):
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    bq = min(block_q, T)
    bk = min(block_k, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    nq, nk = T // bq, S // bk
    q5 = q.reshape(B, T, KV, G, hd)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), causal=causal, window=window,
        block_q=bq, block_k=bk, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, G, hd), lambda b, h, i, j: (b, i, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, G, hd), lambda b, h, i, j: (b, i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, G), jnp.float32),        # running max m
            pltpu.VMEM((bq, G), jnp.float32),        # running denom l
            pltpu.VMEM((bq, G, hd), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(q5, k, v)
    return out.reshape(B, T, H, hd)
