"""Jitted distributed step builders: train / prefill / decode.

Each builder returns (fn, in_shardings, out_shardings, arg_specs) so the
launcher can either execute it (smoke scale) or ``.lower().compile()`` it
against ShapeDtypeStructs (production dry-run).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update


# ----------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, T = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, T), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.num_media_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    elif cfg.uses_media:
        specs["media"] = jax.ShapeDtypeStruct(
            (B, cfg.num_media_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def batch_shardings(cfg, shape, mesh, strategy) -> dict:
    bspec = sh.batch_pspecs(mesh, shape.global_batch, strategy)
    out = {"tokens": P(*bspec, None), "labels": P(*bspec, None)}
    if cfg.is_encoder_decoder:
        out["frames"] = P(*bspec, None, None)
    elif cfg.uses_media:
        out["media"] = P(*bspec, None, None)
    return out


# ----------------------------------------------------------------------
# Train


def make_train_fn(cfg: ModelConfig, mesh: Mesh, strategy: str = "fsdp_tp",
                  opt: AdamWConfig | None = None, shape: ShapeConfig | None = None):
    opt = opt or AdamWConfig()
    p_specs = sh.param_pspecs(cfg, mesh, strategy)
    state_pspecs = {
        "params": p_specs,
        "opt": {"m": p_specs, "v": p_specs, "count": P()},
        "step": P(),
    }

    def train_step(state, batch):
        k = max(cfg.microbatch, 1)
        if k == 1:
            def lf(params):
                return M.loss_fn(cfg, params, batch)

            (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        else:
            # gradient accumulation: k sequential microbatches; activation
            # residency /k at the cost of k-fold weight re-gathers (§Perf)
            mb = jax.tree.map(
                lambda a: a.reshape(k, a.shape[0] // k, *a.shape[1:]), batch)

            def micro(carry, one):
                gsum, lsum = carry
                (_, m), g = jax.value_and_grad(
                    lambda p: M.loss_fn(cfg, p, one), has_aux=True)(state["params"])
                return (jax.tree.map(jnp.add, gsum, g),
                        jax.tree.map(jnp.add, lsum, m)), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            zeros_m = {"loss": 0.0, "ce": 0.0, "moe_aux": 0.0, "router_z": 0.0}
            zeros_m = jax.tree.map(jnp.float32, zeros_m)
            (grads, msum), _ = jax.lax.scan(micro, (zeros_g, zeros_m), mb)
            grads = jax.tree.map(lambda g: g / k, grads)
            metrics = jax.tree.map(lambda m: m / k, msum)
        new_params, new_opt, opt_metrics = adamw_update(
            opt, grads, state["opt"], state["params"])
        metrics = {**metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    in_sh = (state_pspecs, None if shape is None else
             batch_shardings(cfg, shape, mesh, strategy))
    out_sh = (state_pspecs, P())

    def to_named(t):
        return jax.tree.map(lambda p: NamedSharding(mesh, p), t,
                            is_leaf=lambda x: isinstance(x, P))

    jitted = jax.jit(
        train_step,
        in_shardings=(to_named(state_pspecs),
                      to_named(in_sh[1]) if in_sh[1] is not None else None),
        out_shardings=(to_named(state_pspecs), None),
        donate_argnums=(0,),
    )
    return jitted, state_pspecs


def init_train_state(cfg: ModelConfig, rng: jax.Array) -> dict:
    params = M.init_params(cfg, rng)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ModelConfig) -> dict:
    params = M.abstract_params(cfg)
    opt = {"m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
           "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# ----------------------------------------------------------------------
# Prefill (inference: full-sequence forward, next-token logits)


def make_prefill_fn(cfg: ModelConfig, mesh: Mesh, strategy: str = "fsdp_tp",
                    shape: ShapeConfig | None = None):
    p_specs = sh.param_pspecs(cfg, mesh, strategy)

    def prefill(params, batch):
        logits, _ = M.forward(cfg, params, batch)
        return logits[:, -1:, :]

    def to_named(t):
        return jax.tree.map(lambda p: NamedSharding(mesh, p), t,
                            is_leaf=lambda x: isinstance(x, P))

    bsh = None
    if shape is not None:
        bs = dict(batch_shardings(cfg, shape, mesh, strategy))
        bs.pop("labels", None)
        bsh = to_named(bs)
    jitted = jax.jit(prefill, in_shardings=(to_named(p_specs), bsh))
    return jitted, p_specs


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


# ----------------------------------------------------------------------
# Decode (single new token against a seq_len KV cache)


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract decode state via eval_shape (no allocation)."""
    B, C = shape.global_batch, shape.seq_len
    ab_params = M.abstract_params(cfg)
    ctx = None
    if cfg.uses_media:
        ctx = jax.ShapeDtypeStruct((B, cfg.num_media_tokens, cfg.d_model),
                                   jnp.dtype(cfg.dtype))

    def init(params, context):
        return M.init_decode_state(cfg, params, B, C, context=context)

    return jax.eval_shape(init, ab_params, ctx)


def make_decode_fn(cfg: ModelConfig, mesh: Mesh, strategy: str = "fsdp_tp",
                   shape: ShapeConfig | None = None):
    assert shape is not None
    B, C = shape.global_batch, shape.seq_len
    p_specs = sh.param_pspecs(cfg, mesh, strategy)
    st_shapes = decode_state_specs(cfg, shape)
    st_specs = sh.state_pspecs(st_shapes, mesh, B, strategy)

    def step(params, state, tokens):
        return M.decode_step(cfg, params, state, tokens, C)

    def to_named(t):
        return jax.tree.map(lambda p: NamedSharding(mesh, p), t,
                            is_leaf=lambda x: isinstance(x, P))

    tok_sh = NamedSharding(mesh, P(*sh.batch_pspecs(mesh, B, strategy), None))
    jitted = jax.jit(
        step,
        in_shardings=(to_named(p_specs), to_named(st_specs), tok_sh),
        donate_argnums=(1,),
    )
    return jitted, (p_specs, st_specs)


def decode_token_specs(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


partial = partial  # noqa
