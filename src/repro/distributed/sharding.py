"""Logical-axis -> mesh-axis sharding rules.

Strategies (the TonY worker/ps story mapped onto GSPMD):

  fsdp_tp   - production default: parameters shard FSDP-style over the data
              axes (embed dim) and tensor/expert-parallel over the model axis.
  ps        - paper-faithful parameter-server strategy: every parameter is
              sharded across the 'model' axis only ("ps shards"); workers
              (data axis) run pure data-parallel compute, so XLA materializes
              the PS pull/push as all-gather / reduce-scatter on that axis.
  allreduce - replicated parameters, batch sharded over every mesh axis
              (classic synchronous all-reduce data parallelism).

A rule maps a logical axis name to a priority list of mesh-axis candidates;
the first candidate whose size divides the dim (and whose axes are still
unused in that param) wins, otherwise the dim is replicated.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# candidate entries are mesh-axis names or tuples of them
RULES: dict[str, dict[str, list]] = {
    "fsdp_tp": {
        "rwkv_out": ["model"],
        "vocab": ["model"],
        "embed": [("pod", "data"), "data"],
        "mlp": ["model"],
        "heads": ["model"],
        "kv_heads": ["model"],
        "experts": ["model"],
        "lru": ["model"],
        "layers": [],
        "head_dim": [],
        "conv": [],
    },
    "ps": {
        # every param's first shardable dim lands on the PS ("model") axis
        "rwkv_out": ["model"],
        "vocab": ["model"],
        "embed": ["model"],
        "mlp": ["model"],
        "heads": ["model"],
        "kv_heads": ["model"],
        "experts": ["model"],
        "lru": ["model"],
        "layers": [],
        "head_dim": [],
        "conv": [],
    },
    "allreduce": {k: [] for k in
                  ["vocab", "embed", "mlp", "heads", "kv_heads", "experts",
                   "lru", "layers", "head_dim", "conv", "rwkv_out"]},
    # Megatron-style: tensor-parallel over 'model', params REPLICATED across
    # the data axis (no FSDP gathers; gradients all-reduce over data only).
    "tp_only": {
        "rwkv_out": ["model"],
        "vocab": ["model"],
        "embed": [],
        "mlp": ["model"],
        "heads": ["model"],
        "kv_heads": ["model"],
        "experts": ["model"],
        "lru": ["model"],
        "layers": [],
        "head_dim": [],
        "conv": [],
    },
}
STRATEGIES = tuple(RULES)


def _mesh_axis_size(mesh: Mesh, entry) -> int:
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def _axes_of(entry) -> tuple[str, ...]:
    return entry if isinstance(entry, tuple) else (entry,)


def spec_for(axes: tuple, shape: tuple, mesh: Mesh, rules: dict[str, list],
             max_shardings: int | None = None) -> P:
    """Build a PartitionSpec for one param given its logical axes."""
    used: set[str] = set()
    out = []
    n_assigned = 0
    for ax_name, dim in zip(axes, shape):
        assigned = None
        if ax_name is not None and (max_shardings is None or n_assigned < max_shardings):
            for cand in rules.get(ax_name, []):
                cand_axes = _axes_of(cand)
                if any(a not in mesh.shape for a in cand_axes):
                    continue
                if used & set(cand_axes):
                    continue
                if dim % _mesh_axis_size(mesh, cand) == 0 and dim > 0:
                    assigned = cand
                    used.update(cand_axes)
                    n_assigned += 1
                    break
        out.append(assigned)
    return P(*out)


def param_pspecs(cfg, mesh: Mesh, strategy: str = "fsdp_tp"):
    """PartitionSpec tree aligned with init_params(cfg)."""
    from repro.models.layers import is_pspec
    from repro.models.model import build_specs

    rules = RULES[strategy]
    max_sh = 1 if strategy == "ps" else None
    return jax.tree.map(
        lambda s: spec_for(s.axes, s.shape, mesh, rules, max_shardings=max_sh),
        build_specs(cfg), is_leaf=is_pspec)


def _batch_axes(mesh: Mesh, global_batch: int):
    """Pick the widest prefix of ('pod','data','model') that divides batch.
    'model' participates only when every mesh axis is needed (allreduce)."""
    cands = [a for a in ("pod", "data") if a in mesh.shape]
    chosen: list[str] = []
    size = 1
    for a in cands:
        if global_batch % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    return tuple(chosen), size


def batch_pspecs(mesh: Mesh, global_batch: int, strategy: str = "fsdp_tp",
                 include_model: bool = False) -> P:
    axes, size = _batch_axes(mesh, global_batch)
    if (strategy == "allreduce" or include_model) and "model" in mesh.shape:
        if global_batch % (size * mesh.shape["model"]) == 0:
            axes = (*axes, "model")
    return P(axes if axes else None)


def data_pspec(mesh: Mesh, global_batch: int, extra_dims: int,
               strategy: str = "fsdp_tp") -> P:
    b = batch_pspecs(mesh, global_batch, strategy)
    return P(*b, *([None] * extra_dims))


# ----------------------------------------------------------------------
# Decode-state sharding: path-structure driven


def state_pspecs(state_shapes, mesh: Mesh, global_batch: int,
                 strategy: str = "fsdp_tp"):
    """PartitionSpec tree for a decode state (built from eval_shape output).

    KV caches (..., B, C, KV, hd): shard B over the batch axes when possible;
    when B cannot shard (long-context batch=1) shard the cache sequence dim C
    over 'data' (context parallelism).  Recurrent states shard their feature
    dims over 'model'.
    """
    batch_axes, bsize = _batch_axes(mesh, global_batch)
    b_ok = len(batch_axes) > 0
    model = "model" if "model" in mesh.shape else None
    data = "data" if "data" in mesh.shape else None

    def spec(path, leaf):
        name = None
        for k in reversed(path):
            if hasattr(k, "key"):
                name = k.key
                break
        shape = leaf.shape
        rank = len(shape)
        lead = rank
        pre: list = []

        def bspec(bdim):
            return batch_axes if (b_ok and bdim % bsize == 0) else None

        if name in ("k", "v") and rank >= 4:
            # (rep?, B, C, KV, hd)
            pre = [None] * (rank - 4)
            B, C, KV, hd = shape[-4:]
            bs = bspec(B)
            cs = None
            if bs is None and data and C % mesh.shape[data] == 0:
                cs = data
            kvs = model if (model and KV % mesh.shape[model] == 0) else None
            return P(*pre, bs, cs, kvs, None)
        if name == "S" and rank >= 4:          # (rep?, B, H, K, K)
            pre = [None] * (rank - 4)
            B, H = shape[-4], shape[-3]
            hs = model if (model and H % mesh.shape[model] == 0) else None
            return P(*pre, bspec(B), hs, None, None)
        if name == "h" and rank >= 2:          # (rep?, B, w)
            pre = [None] * (rank - 2)
            B, w = shape[-2:]
            ws = model if (model and w % mesh.shape[model] == 0) else None
            return P(*pre, bspec(B), ws)
        if name == "conv" and rank >= 3:       # (rep?, B, cw-1, w)
            pre = [None] * (rank - 3)
            B, _, w = shape[-3:]
            ws = model if (model and w % mesh.shape[model] == 0) else None
            return P(*pre, bspec(B), None, ws)
        if name in ("x_prev", "cmix_prev") and rank >= 2:
            pre = [None] * (rank - 2)
            B, d = shape[-2:]
            ds = model if (model and d % mesh.shape[model] == 0) else None
            return P(*pre, bspec(B), ds)
        if rank == 0:
            return P()
        # fallback: shard batch-like first dim if divisible
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(spec, state_shapes)


def to_shardings(tree_pspecs, mesh: Mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
