from repro.distributed.sharding import (  # noqa: F401
    STRATEGIES,
    batch_pspecs,
    param_pspecs,
    state_pspecs,
)
from repro.distributed.steps import (  # noqa: F401
    make_decode_fn,
    make_prefill_fn,
    make_train_fn,
)
