"""RecurrentGemma-2B — Griffin hybrid: RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427] 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000,
pattern (recurrent, recurrent, local-attn), window 2048, GeGLU, lru_width 2560.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("local", "mlp")),
    mlp_variant="geglu",
    window_size=2048,
    lru_width=2560,
    conv1d_width=4,
    logits_softcap=30.0,
    tie_embeddings=True,
    supports_long_context=True,   # recurrent state + bounded window
    source="arXiv:2402.19427",
)
