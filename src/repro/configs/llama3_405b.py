"""Llama-3 405B — dense GQA flagship.

[arXiv:2407.21783] 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    block_pattern=(("attn", "mlp"),),
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=False,
    decode_window=8192,           # sliding-window decode variant for long_500k
    supports_long_context=True,
    source="arXiv:2407.21783",
)
