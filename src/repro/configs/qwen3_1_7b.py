"""Qwen3-1.7B — dense GQA with per-head QK RMSNorm.

[hf:Qwen/Qwen3-8B family] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, head_dim 128, qk_norm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    block_pattern=(("attn", "mlp"),),
    mlp_variant="swiglu",
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    decode_window=8192,
    supports_long_context=True,
    source="hf:Qwen/Qwen3-8B",
)
