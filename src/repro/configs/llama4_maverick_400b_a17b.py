"""Llama-4 Maverick 400B-A17B — MoE, 128 experts top-1, alternating MoE/dense.

[hf:meta-llama/Llama-4-Scout-17B-16E family] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 + shared expert on every other layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    block_pattern=(("attn", "mlp"), ("attn", "moe")),
    mlp_variant="swiglu",
    num_experts=128,
    experts_per_token=1,
    capacity_factor=1.25,
    shared_expert=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
    decode_window=8192,
    supports_long_context=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
