"""Llama-3.2-Vision-90B — VLM decoder backbone with interleaved cross-attn.

[hf:meta-llama/Llama-3.2-11B-Vision] 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; every 5th layer cross-attends to projected vision
patch embeddings (ViT frontend STUBBED per the assignment carve-out: 4096
precomputed patch embeddings of d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    block_pattern=(
        ("attn", "mlp"), ("attn", "mlp"), ("attn", "mlp"), ("attn", "mlp"),
        ("cross", "mlp"),
    ),
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    num_media_tokens=4096,
    tie_embeddings=False,
    decode_window=8192,           # sliding-window decode variant for long ctx
    supports_long_context=True,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
