"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay time-mix.

[arXiv:2404.05892] 32L d_model=2560 d_ff=8960 vocab=65536; matrix-valued
per-head WKV state with data-dependent decay, token-shift, channel-mix FFN.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # d_model / rwkv_head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    block_pattern=(("rwkv", "cmix"),),
    rwkv_head_dim=64,
    pos_embedding="none",
    tie_embeddings=False,
    supports_long_context=True,   # constant-size recurrent state
    source="arXiv:2404.05892",
)
