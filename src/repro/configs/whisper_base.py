"""Whisper-base — encoder-decoder audio backbone, conv frontend STUBBED.

[arXiv:2212.04356] 6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.
The mel-spectrogram + conv feature extractor is stubbed per the assignment:
``input_specs`` provides 1500 precomputed frame embeddings of d_model.

long_500k is SKIPPED for this arch (full attention, learned positions with a
small native max; no sub-quadratic variant) — see DESIGN.md §Shape skips.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,                 # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    block_pattern=(("attn", "mlp"), ("cross", "mlp")),
    mlp_variant="gelu",
    pos_embedding="learned",
    max_position=65_536,          # backbone-generic table (native whisper: 448)
    num_media_tokens=1500,        # audio frames after the stubbed conv frontend
    tie_embeddings=True,
    supports_long_context=False,  # documented skip for long_500k
    source="arXiv:2212.04356",
)
