"""Architecture registry: one module per assigned architecture.

``get_config("llama3-405b")`` returns the exact assigned full-size config;
``get_smoke_config`` returns the reduced same-family variant for CPU tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig, smoke_variant

ARCH_IDS = [
    "recurrentgemma-2b",
    "llama-3.2-vision-90b",
    "llama3-405b",
    "llama4-maverick-400b-a17b",
    "rwkv6-3b",
    "llama4-scout-17b-a16e",
    "deepseek-coder-33b",
    "whisper-base",
    "qwen3-1.7b",
    "llama3.2-3b",
    # paper-native job config (TonY's canonical workload)
    "tony-paper-mlp",
]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return smoke_variant(get_config(arch_id))


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke_config",
    "all_configs",
    "smoke_variant",
]
