"""Model / job configuration system.

A ``ModelConfig`` fully describes one architecture from the assigned pool.
The layer stack is expressed as a repeating ``block_pattern`` of
``(mixer, mlp)`` kind pairs; ``plan_blocks`` expands it into scan groups so
that HLO size stays O(|pattern|) regardless of depth.

Mixer kinds : attn | local | cross | rglru | rwkv
MLP kinds   : mlp  | moe   | cmix  (rwkv channel-mix)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

BlockDef = tuple[str, str]  # (mixer_kind, mlp_kind)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // num_heads
    block_pattern: tuple[BlockDef, ...] = (("attn", "mlp"),)
    # --- mlp ---
    mlp_variant: str = "swiglu"         # swiglu | geglu | gelu
    # --- moe ---
    num_experts: int = 0
    experts_per_token: int = 1
    capacity_factor: float = 1.25
    shared_expert: bool = False
    router_aux_coef: float = 0.01
    moe_group_size: int = 1024          # tokens per dispatch group (GLaM-style);
                                        # dispatch einsum FLOPs scale with it
    # --- attention ---
    window_size: int = 0                # for 'local' mixer blocks
    use_qk_norm: bool = False
    fused_softmax: bool = False         # softmax(where=): REFUTED in §Perf
                                        # (+9% bytes on this XLA) — off by default
    softmax_dtype: str = "float32"      # f32 (safe) | bfloat16 (§Perf trade)
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"         # rope | learned | none
    max_position: int = 0               # learned pos table size (0 = seq dependent)
    num_media_tokens: int = 0           # vlm patch embeds / audio frames (stub frontend)
    # --- encoder-decoder (audio backbone) ---
    encoder_layers: int = 0
    # --- recurrent ---
    lru_width: int = 0                  # 0 -> d_model
    conv1d_width: int = 4
    rwkv_head_dim: int = 64
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logits_softcap: float = 0.0
    # --- compute policy ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"        # optimizer master dtype
    compute_param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"          # full | dots | nothing (jax.checkpoint policy)
    microbatch: int = 1                 # gradient-accumulation splits per step
    scan_layers: bool = True
    use_pallas: bool = False
    # --- decode policy ---
    decode_window: int = 0              # >0: sliding-window KV cache for decode
                                        # (enables long_500k on dense archs)
    supports_long_context: bool = True  # False -> skip long_500k (documented)
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def uses_media(self) -> bool:
        return self.num_media_tokens > 0

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def layer_defs(self) -> list[BlockDef]:
        """The full, ordered list of (mixer, mlp) blocks for the decoder."""
        pat = self.block_pattern
        out = []
        for i in range(self.num_layers):
            out.append(pat[i % len(pat)])
        return out

    def plan_blocks(self) -> list[tuple[tuple[BlockDef, ...], int, int]]:
        """Group the stack into scan groups.

        Returns a list of (superblock, repeat, n_layers_covered).  A
        superblock is one full pattern repetition scanned ``repeat`` times;
        a trailing remainder (num_layers % len(pattern)) is emitted as a
        group with repeat == 1 per leftover block.
        """
        pat = self.block_pattern
        k, r = divmod(self.num_layers, len(pat))
        groups: list[tuple[tuple[BlockDef, ...], int, int]] = []
        if k > 0:
            groups.append((pat, k, k * len(pat)))
        for j in range(r):
            groups.append(((pat[j],), 1, 1))
        return groups

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        total = V * d                      # embedding
        total += d                         # final norm
        if not self.tie_embeddings:
            total += V * d
        if self.pos_embedding == "learned" and self.max_position:
            total += self.max_position * d
        if self.encoder_layers:
            total += d + self.num_media_tokens * d  # enc final norm + enc pos
        enc_blocks = [("attn", "mlp")] * self.encoder_layers
        for mixer, mlp in self.layer_defs() + enc_blocks:
            total += 2 * d                 # two pre-norms
            if mixer in ("attn", "local", "cross"):
                total += d * H * hd + 2 * d * KV * hd + H * hd * d
                if self.use_qk_norm:
                    total += 2 * hd
            elif mixer == "rglru":
                w = self.resolved_lru_width
                total += 2 * d * w         # x branch + gate branch
                total += self.conv1d_width * w + w
                total += 2 * w * w + 2 * w  # input/recurrence gates
                total += w                 # log-lambda
                total += w * d             # out proj
            elif mixer == "rwkv":
                total += 5 * d             # token-shift mus (r,k,v,w,g)
                total += 6 * d * d         # r,k,v,g,decay,out projections
                total += 4 * d             # decay_base, u_bonus, ln scale/bias
            if mlp == "mlp":
                n_in = 2 if self.mlp_variant in ("swiglu", "geglu") else 1
                total += n_in * d * f + f * d
            elif mlp == "cmix":
                total += d * f + f * d + d * d + 2 * d
            elif mlp == "moe":
                E = self.num_experts
                total += d * E             # router
                total += E * (2 * d * f + f * d)
                if self.shared_expert:
                    total += 2 * d * f + f * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        d, f, E = self.d_model, self.d_ff, self.num_experts
        expert_p = 2 * d * f + f * d
        n_moe = sum(1 for _, m in self.layer_defs() if m == "moe")
        inactive = n_moe * (E - self.experts_per_token) * expert_p
        return self.param_count() - inactive


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family: <=2 pattern repeats, d_model<=512,
    <=4 experts — used by CPU smoke tests."""
    pat_len = len(cfg.block_pattern)
    layers = min(cfg.num_layers, max(pat_len, 2 * pat_len if pat_len <= 3 else pat_len))
    d = min(cfg.d_model, 256)
    hd = 32
    heads = max(1, d // 64)
    kv = max(1, min(cfg.num_kv_heads, heads))
    return cfg.replace(
        num_layers=layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        lru_width=min(cfg.resolved_lru_width, d) if cfg.lru_width or cfg.arch_type in ("hybrid",) else 0,
        rwkv_head_dim=32,
        window_size=min(cfg.window_size, 64) if cfg.window_size else 0,
        num_media_tokens=min(cfg.num_media_tokens, 16) if cfg.num_media_tokens else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        max_position=min(cfg.max_position, 4096) if cfg.max_position else 0,
        decode_window=min(cfg.decode_window, 64) if cfg.decode_window else 0,
        remat=False,
        dtype="float32",
        compute_param_dtype="float32",
    )
