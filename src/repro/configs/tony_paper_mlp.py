"""TonY paper-native workload: the kind of model LinkedIn ran through TonY in
2019 — a modest dense network trained with the parameter-server strategy
(TensorFlow-on-YARN era).  We keep it as a small dense transformer so the
same substrate serves it; what makes it "paper-native" is the *job shape*
(worker/ps heterogeneous containers, PS distribution strategy), exercised by
examples/quickstart.py and the orchestration benchmarks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tony-paper-mlp",
    arch_type="dense",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=8192,
    block_pattern=(("attn", "mlp"),),
    mlp_variant="gelu",
    pos_embedding="learned",
    max_position=4096,
    tie_embeddings=True,
    remat=False,
    dtype="float32",
    compute_param_dtype="float32",
    source="OpML'19 TonY (paper-native job)",
)
