"""Llama-4 Scout 17B-A16E — MoE, 16 experts top-1 on every layer.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 16e top-1 + shared expert.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    block_pattern=(("attn", "moe"),),
    mlp_variant="swiglu",
    num_experts=16,
    experts_per_token=1,
    capacity_factor=1.25,
    shared_expert=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
    decode_window=8192,
    supports_long_context=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
