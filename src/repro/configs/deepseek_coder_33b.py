"""DeepSeek-Coder 33B — dense llama-arch code model.

[arXiv:2401.14196] 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19_200,
    vocab_size=32_256,
    block_pattern=(("attn", "mlp"),),
    mlp_variant="swiglu",
    rope_theta=100_000.0,
    tie_embeddings=False,
    decode_window=8192,
    supports_long_context=True,
    source="arXiv:2401.14196",
)
