"""AdamW as a pure pytree transform (no external deps).

Moments live in f32 and share the parameter sharding (same pytree
structure), so the optimizer states shard identically to params under the
dry-run meshes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)  # noqa: E731
    return {"m": zeros(params), "v": zeros(params), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    lr = cfg.lr(count) if callable(cfg.lr) else cfg.lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    m = jax.tree.map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * jnp.square(g),
                     state["v"], grads)

    def upd(p, mm, vv):
        step = lr * (mm / b1c) / (jnp.sqrt(vv / b2c) + cfg.eps)
        if cfg.weight_decay > 0:
            step = step + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, {"grad_norm": gnorm}
