"""Learning-rate schedules as step -> lr callables."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak: float, warmup_steps: int):
    def f(step):
        return peak * jnp.minimum(1.0, step.astype(jnp.float32) / max(warmup_steps, 1))
    return f


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * jnp.minimum(1.0, s / max(warmup_steps, 1))
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)
    return f
