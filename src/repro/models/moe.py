"""Mixture-of-Experts layer: top-k routing with capacity-limited einsum
dispatch/combine (the classic TPU-native Mesh-TF/GLaM formulation), plus a
weight-gather path for tiny decode batches (N < E).

Sharding intent: token groups shard over the data axes, experts shard over the
model axis — GSPMD inserts the all-to-all between token- and expert-major
layouts, which is exactly the MoE collective the roofline tracks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import PSpec, constrain




def moe_specs(cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "router": PSpec((d, E), ("embed", "experts"), fan_in=d),
        "w_gate": PSpec((E, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w_up": PSpec((E, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w_down": PSpec((E, f, d), ("experts", "mlp", "embed"), fan_in=f),
    }
    if cfg.shared_expert:
        p["shared"] = {
            "wi_gate": PSpec((d, f), ("embed", "mlp")),
            "wi_up": PSpec((d, f), ("embed", "mlp")),
            "wo": PSpec((f, d), ("mlp", "embed")),
        }
    return p


def _expert_ffn(w, h):
    """h: (..., c, d) grouped expert inputs with leading expert dim e."""
    gate = jnp.einsum("gecd,edf->gecf", h, w["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", h, w["w_up"])
    return jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, w["w_down"])


def apply_moe(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, dict]:
    """x: (B, T, d) -> (out, aux) with load-balance + router-z aux losses."""
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    N = B * T
    flat = x.reshape(N, d)

    logits = flat.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)

    if N < E:
        out = _decode_gather(cfg, p, flat, probs)
        aux = {"moe_aux": jnp.zeros((), jnp.float32), "router_z": jnp.zeros((), jnp.float32)}
    else:
        out, aux = _einsum_dispatch(cfg, p, flat, probs, logits)

    if cfg.shared_expert:
        s = p["shared"]
        shared = (jax.nn.silu(flat @ s["wi_gate"]) * (flat @ s["wi_up"])) @ s["wo"]
        out = out + shared
    return out.reshape(B, T, d), aux


def _einsum_dispatch(cfg, p, flat, probs, logits):
    N, d = flat.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    g = min(cfg.moe_group_size, N)
    while N % g:
        g //= 2
    G, S = N // g, g
    cap = max(1, int(S / E * cfg.capacity_factor * k))

    probs_g = probs.reshape(G, S, E)
    xg = flat.reshape(G, S, d)

    # top-k (k=1 for the assigned archs, general code kept for k>1)
    combine = jnp.zeros((G, S, E, cap), jnp.float32)
    gates_left = probs_g
    position_base = jnp.zeros((G, E), jnp.int32)
    aux_frac = jnp.zeros((G, E), jnp.float32)
    for _ in range(k):
        gate, idx = jax.lax.top_k(gates_left, 1)           # (G,S,1)
        gate, idx = gate[..., 0], idx[..., 0]              # (G,S)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G,S,E)
        aux_frac = aux_frac + onehot.mean(axis=1)
        # position of each token within its expert queue
        pos_in_e = (jnp.cumsum(onehot, axis=1) - onehot) + position_base[:, None, :]
        pos = jnp.einsum("gse,gse->gs", pos_in_e, onehot)  # (G,S)
        keep = pos < cap
        poh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)  # (G,S,cap)
        combine = combine + (gate * keep)[..., None, None] * onehot[..., None] * poh[:, :, None, :]
        position_base = position_base + onehot.sum(axis=1).astype(jnp.int32)
        gates_left = gates_left * (1.0 - onehot)
    dispatch = (combine > 0).astype(flat.dtype)            # (G,S,E,cap)

    h = jnp.einsum("gsec,gsd->gecd", dispatch, xg)          # all-to-all boundary
    h = constrain(h, "batch", "model", None, None)          # expert-parallel
    y = _expert_ffn(p, h.astype(flat.dtype))
    y = constrain(y, "batch", "model", None, None)
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(flat.dtype), y)

    # Switch-style load balance: E * mean_e(frac_tokens_e * mean_prob_e)
    mean_prob = probs_g.mean(axis=1)                        # (G,E)
    lb = E * jnp.mean(jnp.sum((aux_frac / k) * mean_prob, axis=-1))
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out.reshape(N, d), {"moe_aux": lb, "router_z": zl}


def _decode_gather(cfg, p, flat, probs):
    """Tiny-batch decode: gather the selected expert's weights per token.
    FLOPs = active params only; bytes = gathered weights (the real
    weight-movement cost of small-batch MoE serving)."""
    N, d = flat.shape
    idx = jnp.argmax(probs, axis=-1)                        # (N,) top-1
    gate = jnp.max(probs, axis=-1)
    wg = jnp.take(p["w_gate"], idx, axis=0)                 # (N, d, f)
    wu = jnp.take(p["w_up"], idx, axis=0)
    wd = jnp.take(p["w_down"], idx, axis=0)
    h = jax.nn.silu(jnp.einsum("nd,ndf->nf", flat, wg)) * jnp.einsum("nd,ndf->nf", flat, wu)
    out = jnp.einsum("nf,nfd->nd", h, wd)
    return out * gate[:, None].astype(out.dtype)
