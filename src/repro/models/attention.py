"""GQA attention: full / local(sliding-window) / cross variants, full-sequence
and single-token KV-cache decode paths (linear + ring-buffer caches).

The jnp implementation here is the reference path; ``cfg.use_pallas`` routes
full-sequence self-attention through the Pallas flash kernel (TPU target,
interpret-validated on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (PSpec, apply_rope, constrain,
                                 constrain_any, rms_norm, rope_angles)

NEG_INF = -2.0e38


def attn_specs(cfg, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": PSpec((d, H, hd), ("embed", "heads", "head_dim"), fan_in=d),
        "wk": PSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wv": PSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), fan_in=d),
        "wo": PSpec((H, hd, d), ("heads", "head_dim", "embed"), fan_in=H * hd),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = PSpec((hd,), ("head_dim",), init="zeros")
        p["k_norm"] = PSpec((hd,), ("head_dim",), init="zeros")
    return p


def _gqa_scores(q, k):
    """q: (B,Tq,H,hd), k: (B,Tk,KV,hd) -> scores (B,H,Tq,Tk).

    Q-head-major layout: the O(T^2) score buffer carries the full H dim so it
    shards over the 'model' axis even when num_kv_heads < model-axis size
    (GQA kv=8 on a 16-way TP mesh would otherwise replicate it)."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    kr = jnp.repeat(k, H // KV, axis=2)          # (B,Tk,H,hd)
    return jnp.einsum("bthd,bshd->bhts", q, kr)


def _gqa_out(probs, v):
    """probs: (B,H,Tq,Tk), v: (B,Tk,KV,hd) -> (B,Tq,H,hd)."""
    B, H, Tq, _ = probs.shape
    KV = v.shape[2]
    vr = jnp.repeat(v, H // KV, axis=2)          # (B,Tk,H,hd)
    return jnp.einsum("bhts,bshd->bthd", probs, vr)


def masked_softmax(scores: jax.Array, mask: jax.Array | None,
                   fused: bool = True,
                   softmax_dtype: str = "float32") -> jax.Array:
    """fused=True (§Perf): softmax(where=) masks inside the reduction — one
    fewer materialized (B,H,T,S) f32 buffer than the where()+softmax form
    (jax's where-softmax already zeroes masked positions).
    softmax_dtype='bfloat16' keeps the scores buffer half-width (§Perf
    accuracy/memory trade, default f32)."""
    s = scores.astype(jnp.dtype(softmax_dtype))
    if mask is None:
        return jax.nn.softmax(s, axis=-1)
    if fused:
        return jax.nn.softmax(s, axis=-1, where=mask)
    s = jnp.where(mask, s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def make_mask(Tq: int, Tk: int, *, causal: bool, window: int, q_offset=0):
    """(Tq, Tk) boolean mask built from iotas (no O(T^2) host tensor)."""
    if not causal and window <= 0:
        return None
    qpos = jnp.arange(Tq)[:, None] + q_offset
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return mask


def self_attention(cfg, p, x, *, causal: bool, window: int, positions=None):
    """Full-sequence self attention. x: (B,T,d)."""
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        pos = positions if positions is not None else jnp.arange(T)
        sin, cos = rope_angles(pos, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    q = constrain_any(q, ("batch", None, "model", None),
                      ("batch", "model", None, None))
    if cfg.use_pallas and causal and cfg.pos_embedding != "learned":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        scores = _gqa_scores(q, k) / jnp.sqrt(hd).astype(jnp.float32)
        scores = constrain_any(scores, ("batch", "model", None, None),
                               ("batch", None, "model", None))
        mask = make_mask(T, T, causal=causal, window=window)
        probs = masked_softmax(scores, mask, cfg.fused_softmax,
                               cfg.softmax_dtype).astype(q.dtype)
        out = _gqa_out(probs, v)
    out = constrain_any(out, ("batch", None, "model", None),
                        ("batch", "model", None, None))
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def cross_attention(cfg, p, x, media_kv):
    """x: (B,T,d); media_kv: precomputed (k, v) each (B,M,KV,hd)."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = media_kv
    q = constrain_any(q, ("batch", None, "model", None),
                      ("batch", "model", None, None))
    scores = _gqa_scores(q, k) / jnp.sqrt(hd).astype(jnp.float32)
    scores = constrain_any(scores, ("batch", "model", None, None),
                           ("batch", None, "model", None))
    probs = masked_softmax(scores, None, cfg.fused_softmax,
                           cfg.softmax_dtype).astype(q.dtype)
    out = _gqa_out(probs, v)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def media_kv(cfg, p, media):
    """Precompute cross-attention K/V from media embeddings (B,M,d)."""
    k = jnp.einsum("bmd,dhk->bmhk", media, p["wk"])
    v = jnp.einsum("bmd,dhk->bmhk", media, p["wv"])
    if cfg.use_qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


# ----------------------------------------------------------------------
# Decode (single new token, KV cache)


def init_cache(cfg, batch: int, capacity: int, dtype) -> dict:
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, capacity, KV, hd), dtype),
        "v": jnp.zeros((batch, capacity, KV, hd), dtype),
    }


def decode_self_attention(cfg, p, x_t, cache, pos, *, window: int):
    """One-token decode. x_t: (B,1,d); cache k/v: (B,C,KV,hd); pos: scalar.

    When ``window > 0`` (or capacity < full seq) the cache is a ring buffer:
    slot = pos % C.  Returns (out (B,1,d), new_cache).
    """
    B = x_t.shape[0]
    hd = cfg.resolved_head_dim
    C = cache["k"].shape[1]
    q = jnp.einsum("btd,dhk->bthk", x_t, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x_t, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x_t, p["wv"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        sin, cos = rope_angles(pos[None], hd, cfg.rope_theta)  # (1, hd/2)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)  # rotated at true position before caching
    slot = jnp.mod(pos, C)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    # slot i holds absolute position p_i = pos - ((pos - i) mod C)
    idx = jnp.arange(C)
    slot_pos = pos - jnp.mod(pos - idx, C)
    valid = slot_pos >= 0
    if window > 0:
        valid &= slot_pos > pos - window
    scores = _gqa_scores(q, ck) / jnp.sqrt(hd).astype(jnp.float32)
    probs = masked_softmax(scores, valid[None, None, None, :],
                           cfg.fused_softmax, cfg.softmax_dtype).astype(q.dtype)
    out = _gqa_out(probs, cv)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return out, {"k": ck, "v": cv}


def decode_cross_attention(cfg, p, x_t, media_cache):
    """Cross-attn during decode against precomputed media K/V."""
    return cross_attention(cfg, p, x_t, (media_cache["k"], media_cache["v"]))
