"""Recurrent mixers.

RG-LRU (Griffin / RecurrentGemma): diagonal gated linear recurrence, computed
with ``jax.lax.associative_scan`` over time — the TPU-native adaptation of the
GPU sequential kernel (log-depth, MXU-free elementwise work).

RWKV-6 (Finch): matrix-valued per-head WKV state with data-dependent decay,
computed with an exact sequential ``lax.scan`` in the reference path (compact
HLO; the Pallas ``wkv`` kernel is the TPU perf path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (
    PSpec,
    causal_conv1d,
    conv1d_decode,
    group_norm_heads,
    token_shift,
)

RGLRU_C = 8.0  # Griffin's fixed temperature on the recurrence gate


# ----------------------------------------------------------------------
# RG-LRU block


def rglru_specs(cfg) -> dict:
    d, w = cfg.d_model, cfg.resolved_lru_width
    cw = cfg.conv1d_width
    return {
        "w_x": PSpec((d, w), ("embed", "lru")),
        "w_gate": PSpec((d, w), ("embed", "lru")),
        "conv_w": PSpec((cw, w), ("conv", "lru"), fan_in=cw),
        "conv_b": PSpec((w,), ("lru",), init="zeros"),
        "gate_a": PSpec((w, w), ("lru", "lru")),
        "gate_a_b": PSpec((w,), ("lru",), init="zeros"),
        "gate_x": PSpec((w, w), ("lru", "lru")),
        "gate_x_b": PSpec((w,), ("lru",), init="zeros"),
        "log_lambda": PSpec((w,), ("lru",), init="lru_lambda"),
        "w_out": PSpec((w, d), ("lru", "embed")),
    }


def _rglru_coeffs(p, x):
    """Per-step recurrence coefficients. x: (..., w) post-conv branch.
    Returns (a, b) with h_t = a_t * h_{t-1} + b_t, computed in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["gate_a"].astype(jnp.float32) + p["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["gate_x"].astype(jnp.float32) + p["gate_x_b"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["log_lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalization (Griffin eq. 4), stable form
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * xf)
    return a, b


def rglru_scan(p, x, use_pallas: bool = False):
    """Scan over time. x: (B, T, w) -> h: (B, T, w) (f32).

    Reference path: associative_scan (log-depth, TPU-native). Pallas path:
    the chunked ``linear_scan`` kernel."""
    a, b = _rglru_coeffs(p, x)
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.linear_scan(a, b)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_cum
    return h


def apply_rglru(cfg, p, x):
    """Full Griffin recurrent block. x: (B, T, d) -> (B, T, d)."""
    from repro.models.layers import constrain
    branch = constrain(x @ p["w_x"], "batch", None, "model")
    gate = jax.nn.gelu(x @ p["w_gate"])
    branch = causal_conv1d(branch, p["conv_w"], p["conv_b"])
    h = rglru_scan(p, branch, use_pallas=cfg.use_pallas).astype(x.dtype)
    return (h * gate) @ p["w_out"]


def rglru_init_state(cfg, batch: int) -> dict:
    w, cw = cfg.resolved_lru_width, cfg.conv1d_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, w), jnp.float32),
    }


def decode_rglru(cfg, p, x_t, state):
    """One-step decode. x_t: (B, 1, d) -> (out (B,1,d), new_state)."""
    xt = x_t[:, 0, :]
    branch = xt @ p["w_x"]
    gate = jax.nn.gelu(xt @ p["w_gate"])
    branch, conv_state = conv1d_decode(
        branch.astype(jnp.float32), state["conv"], p["conv_w"].astype(jnp.float32),
        p["conv_b"].astype(jnp.float32))
    a, b = _rglru_coeffs(p, branch)
    h = a * state["h"] + b
    out = ((h.astype(xt.dtype) * gate) @ p["w_out"])[:, None, :]
    return out, {"h": h, "conv": conv_state}


# ----------------------------------------------------------------------
# RWKV-6 time-mix


def rwkv_specs(cfg) -> dict:
    d = cfg.d_model
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "mu_r": PSpec((d,), ("embed",), init="ones"),
        "mu_k": PSpec((d,), ("embed",), init="ones"),
        "mu_v": PSpec((d,), ("embed",), init="ones"),
        "mu_w": PSpec((d,), ("embed",), init="ones"),
        "mu_g": PSpec((d,), ("embed",), init="ones"),
        "w_r": PSpec((d, d), ("embed", "rwkv_out")),
        "w_k": PSpec((d, d), ("embed", "rwkv_out")),
        "w_v": PSpec((d, d), ("embed", "rwkv_out")),
        "w_g": PSpec((d, d), ("embed", "rwkv_out")),
        "w_decay": PSpec((d, d), ("embed", "rwkv_out")),   # data-dependent decay proj
        "decay_base": PSpec((H, K), ("heads", "head_dim"), init="zeros"),
        "u_bonus": PSpec((H, K), ("heads", "head_dim"), init="zeros"),
        "ln_scale": PSpec((H, K), ("heads", "head_dim"), init="ones"),
        "ln_bias": PSpec((H, K), ("heads", "head_dim"), init="zeros"),
        "w_out": PSpec((d, d), ("rwkv_out", "embed")),
    }


def _rwkv_proj(cfg, p, x, shifted):
    """Token-shift lerps + projections -> r,k,v,g,(log)w heads."""
    B, T, d = x.shape
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim

    def lerp(mu):
        return x + (shifted - x) * mu

    from repro.models.layers import constrain_any as _ca
    r = _ca((lerp(p["mu_r"]) @ p["w_r"]).reshape(B, T, H, K),
            ("batch", None, "model", None), ("batch", None, None, "model"))
    k = _ca((lerp(p["mu_k"]) @ p["w_k"]).reshape(B, T, H, K),
            ("batch", None, "model", None), ("batch", None, None, "model"))
    v = _ca((lerp(p["mu_v"]) @ p["w_v"]).reshape(B, T, H, K),
            ("batch", None, "model", None), ("batch", None, None, "model"))
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["w_g"])
    # Finch: per-channel decay w_t = exp(-exp(base + f(x_t))), in f32
    dd = (lerp(p["mu_w"]) @ p["w_decay"]).reshape(B, T, H, K).astype(jnp.float32)
    log_w = -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32) + jnp.tanh(dd), -8.0, 4.0))
    return r, k, v, g, log_w


def _wkv_step(state, inputs, u):
    """state: (B,H,K,K) f32; r,k,v: (B,H,K); log_w: (B,H,K)."""
    r, k, v, log_w = inputs
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]              # (B,H,K,K)
    y = jnp.einsum("bhk,bhkv->bhv", rf, state + u[..., :, None] * kv)
    new_state = jnp.exp(log_w)[..., :, None] * state + kv
    return new_state, y


def apply_rwkv(cfg, p, x, *, return_state=False, init_state=None):
    """Full-sequence RWKV-6 time-mix. x: (B,T,d)."""
    B, T, d = x.shape
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    shifted = token_shift(x)
    r, k, v, g, log_w = _rwkv_proj(cfg, p, x, shifted)
    u = p["u_bonus"].astype(jnp.float32)

    S0 = init_state if init_state is not None else jnp.zeros((B, H, K, K), jnp.float32)

    if cfg.use_pallas and init_state is None and not return_state:
        from repro.kernels import ops as kops
        y = kops.wkv(r, k, v, log_w.astype(r.dtype), p["u_bonus"].astype(r.dtype))
    else:
        def body(state, ins):
            return _wkv_step(state, ins, u)

        xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), log_w.transpose(1, 0, 2, 3))
        S, ys = jax.lax.scan(body, S0, xs)
        y = ys.transpose(1, 0, 2, 3)                       # (B,T,H,K)
    y = group_norm_heads(y, p["ln_scale"], p["ln_bias"], cfg.norm_eps)
    out = (y.reshape(B, T, d).astype(x.dtype) * g) @ p["w_out"]
    if return_state:
        return out, S
    return out


def rwkv_init_state(cfg, batch: int) -> dict:
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "S": jnp.zeros((batch, H, K, K), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def decode_rwkv(cfg, p, x_t, state):
    """One-step decode. x_t: (B,1,d)."""
    B, _, d = x_t.shape
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    shifted = token_shift(x_t, state["x_prev"].astype(x_t.dtype))
    r, k, v, g, log_w = _rwkv_proj(cfg, p, x_t, shifted)
    u = p["u_bonus"].astype(jnp.float32)
    S, y = _wkv_step(state["S"], (r[:, 0], k[:, 0], v[:, 0], log_w[:, 0]), u)
    y = group_norm_heads(y[:, None], p["ln_scale"], p["ln_bias"], cfg.norm_eps)
    out = (y.reshape(B, 1, d).astype(x_t.dtype) * g) @ p["w_out"]
    return out, {"S": S, "x_prev": x_t[:, 0, :].astype(jnp.float32)}


def cmix_init_state(cfg, batch: int) -> jax.Array:
    return jnp.zeros((batch, cfg.d_model), jnp.float32)
