from repro.models.model import (  # noqa: F401
    abstract_params,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    logical_axes,
    loss_fn,
)
