"""Composable transformer stack covering all assigned architecture families.

The decoder is a sequence of *scan groups* derived from
``ModelConfig.plan_blocks()``: each group is one full ``block_pattern``
repetition whose parameters are stacked on a leading 'layers' axis and
iterated with ``lax.scan`` (HLO size O(|pattern|), not O(depth)).

Public API:
  init_params / abstract_params / logical_axes
  forward(cfg, params, tokens, context)        - full-seq (train / prefill)
  loss_fn(cfg, params, batch)                  - CE + MoE aux losses
  init_decode_state / decode_step              - single-token KV-cache decode
  encode(cfg, params, frames)                  - enc-dec (audio) encoder
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import (
    PSpec,
    abstract_tree,
    apply_cmix,
    apply_mlp,
    axes_tree,
    cmix_specs,
    constrain,
    init_tree,
    mlp_specs,
    norm_spec,
    rms_norm,
    softcap,
    stack_specs,
    token_shift,
)

ZERO_AUX = lambda: {"moe_aux": jnp.zeros((), jnp.float32),  # noqa: E731
                    "router_z": jnp.zeros((), jnp.float32)}


# ----------------------------------------------------------------------
# Parameter specs


def _block_specs(cfg: ModelConfig, bdef) -> dict:
    mixer, mlpk = bdef
    p = {"ln1": norm_spec(cfg.d_model), "ln2": norm_spec(cfg.d_model)}
    if mixer in ("attn", "local", "cross"):
        p["mixer"] = attn.attn_specs(cfg, cross=(mixer == "cross"))
    elif mixer == "rglru":
        p["mixer"] = rec.rglru_specs(cfg)
    elif mixer == "rwkv":
        p["mixer"] = rec.rwkv_specs(cfg)
    else:
        raise ValueError(mixer)
    if mlpk == "mlp":
        p["mlp"] = mlp_specs(cfg)
    elif mlpk == "moe":
        p["mlp"] = moe_mod.moe_specs(cfg)
    elif mlpk == "cmix":
        p["mlp"] = cmix_specs(cfg)
    else:
        raise ValueError(mlpk)
    return p


def _group_specs(cfg: ModelConfig, superblock, repeat: int):
    block_list = tuple(_block_specs(cfg, b) for b in superblock)
    if repeat == 1:
        return block_list
    return tuple(stack_specs(b, repeat) for b in block_list)


def build_specs(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    specs: dict = {
        "embed": PSpec((V, d), ("vocab", "embed"), fan_in=d),
        "decoder": [
            _group_specs(cfg, sb, rep) for sb, rep, _ in cfg.plan_blocks()
        ],
        "final_norm": norm_spec(d),
    }
    if cfg.pos_embedding == "learned":
        specs["pos_table"] = PSpec((cfg.max_position, d), (None, "embed"), fan_in=d)
    if not cfg.tie_embeddings:
        specs["lm_head"] = PSpec((d, V), ("embed", "vocab"), fan_in=d)
    if cfg.is_encoder_decoder:
        enc_pat = (("attn", "mlp"),)
        specs["encoder"] = [_group_specs(cfg, enc_pat, cfg.encoder_layers)]
        specs["enc_final_norm"] = norm_spec(d)
        specs["enc_pos_table"] = PSpec((cfg.num_media_tokens, d), (None, "embed"), fan_in=d)
    return specs


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict:
    return init_tree(build_specs(cfg), rng, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig, dtype=None) -> dict:
    return abstract_tree(build_specs(cfg), jnp.dtype(dtype or cfg.param_dtype))


def logical_axes(cfg: ModelConfig) -> dict:
    return axes_tree(build_specs(cfg))


# ----------------------------------------------------------------------
# Block application (full sequence)


def _apply_block(cfg, bdef, p, x, context, aux, *, causal=True):
    mixer, mlpk = bdef
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        y = attn.self_attention(cfg, p["mixer"], h, causal=causal, window=0)
    elif mixer == "local":
        y = attn.self_attention(cfg, p["mixer"], h, causal=causal, window=cfg.window_size)
    elif mixer == "cross":
        kv = attn.media_kv(cfg, p["mixer"], context)
        y = attn.cross_attention(cfg, p["mixer"], h, kv)
    elif mixer == "rglru":
        y = rec.apply_rglru(cfg, p["mixer"], h)
    elif mixer == "rwkv":
        y = rec.apply_rwkv(cfg, p["mixer"], h)
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if mlpk == "mlp":
        x = x + apply_mlp(cfg, p["mlp"], h2)
    elif mlpk == "moe":
        y, a = moe_mod.apply_moe(cfg, p["mlp"], h2)
        aux = {k: aux[k] + a[k] for k in aux}
        x = x + y
    elif mlpk == "cmix":
        x = x + apply_cmix(cfg, p["mlp"], h2, token_shift(h2))
    return x, aux


def _unstack(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _remat(cfg, fn):
    """jax.checkpoint with the configured save policy (§Perf lever):
    'full' recomputes everything (min memory), 'dots' saves matmul outputs
    (less recompute, more residency), 'nothing' disables remat."""
    if not cfg.remat or cfg.remat_policy == "nothing":
        return fn
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def _run_groups(cfg, groups_params, plan, x, context, *, causal=True):
    aux = ZERO_AUX()
    for (superblock, repeat, _), gp in zip(plan, groups_params):
        if repeat == 1:
            for bdef, bp in zip(superblock, gp):
                x, aux = _apply_block(cfg, bdef, bp, x, context, aux, causal=causal)
        elif not cfg.scan_layers:
            # unrolled: exact per-layer HLO (used by the dry-run analysis mode
            # because XLA cost_analysis counts while-loop bodies once); remat
            # still applies per superblock so recompute FLOPs stay faithful
            def one_rep(carry, bps, superblock=superblock):
                xx, ax = carry
                for bdef, bp in zip(superblock, bps):
                    xx, ax = _apply_block(cfg, bdef, bp, xx, context, ax,
                                          causal=causal)
                return xx, ax

            one_rep = _remat(cfg, one_rep)
            for i in range(repeat):
                x, aux = one_rep((x, aux), _unstack(gp, i))
        else:
            def body(carry, xs, superblock=superblock):
                xx, ax = carry
                for bdef, bp in zip(superblock, xs):
                    xx, ax = _apply_block(cfg, bdef, bp, xx, context, ax, causal=causal)
                return (xx, ax), None

            body = _remat(cfg, body)
            (x, aux), _ = jax.lax.scan(body, (x, aux), gp)
    return x, aux


# ----------------------------------------------------------------------
# Full-sequence forward


def _embed(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.pos_embedding == "learned":
        T = tokens.shape[1]
        pos = params["pos_table"][:T].astype(x.dtype)
        x = x + pos[None]
    return x


def _logits(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("btd,dv->btv", x, params["lm_head"].astype(x.dtype))
    logits = constrain(logits, "batch", None, "model")
    return softcap(logits.astype(jnp.float32), cfg.logits_softcap)


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Audio encoder over stub frame embeddings (B, M, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos_table"][None].astype(
        jnp.dtype(cfg.dtype))
    plan = [((("attn", "mlp"),), cfg.encoder_layers, cfg.encoder_layers)]
    x, _ = _run_groups(cfg, params["encoder"], plan, x, None, causal=False)
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _resolve_context(cfg, params, batch):
    if cfg.is_encoder_decoder:
        return encode(cfg, params, batch["frames"])
    if cfg.uses_media:
        return batch["media"].astype(jnp.dtype(cfg.dtype))
    return None


def forward(cfg: ModelConfig, params: dict, batch: dict):
    """batch: {'tokens': (B,T) int32, ['media'|'frames']: (B,M,d)}.
    Returns (logits (B,T,V) f32, aux)."""
    compute_params = jax.tree.map(
        lambda a: a.astype(jnp.dtype(cfg.compute_param_dtype))
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    context = _resolve_context(cfg, compute_params, batch)
    x = _embed(cfg, compute_params, batch["tokens"])
    x, aux = _run_groups(cfg, compute_params["decoder"], cfg.plan_blocks(), x, context)
    return _logits(cfg, compute_params, x), aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    # vocab-sharding-friendly CE: no gather along the (model-sharded) V dim —
    # the label logit is extracted with an iota mask so V stays sharded and
    # only (B,T)-shaped partial reductions cross the mesh.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.squeeze(m, -1) + jnp.log(
        jnp.sum(jnp.exp(logits - m), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    ll = label_logit - lse
    ce = -jnp.mean(ll)
    loss = ce + cfg.router_aux_coef * aux["moe_aux"] + 1e-3 * aux["router_z"]
    metrics = {"loss": loss, "ce": ce, "moe_aux": aux["moe_aux"],
               "router_z": aux["router_z"]}
    return loss, metrics


# ----------------------------------------------------------------------
# Decode


def _attn_capacity(cfg, mixer, cache_len):
    if mixer == "local":
        return min(cfg.window_size, cache_len)
    if cfg.decode_window and cache_len > cfg.decode_window:
        return cfg.decode_window
    return cache_len


def _attn_window(cfg, mixer, cache_len):
    if mixer == "local":
        return cfg.window_size
    if cfg.decode_window and cache_len > cfg.decode_window:
        return cfg.decode_window
    return 0


def _block_cache(cfg, bdef, batch, cache_len, dtype):
    mixer, mlpk = bdef
    c: dict = {}
    if mixer in ("attn", "local"):
        c["kv"] = attn.init_cache(cfg, batch, _attn_capacity(cfg, mixer, cache_len), dtype)
    elif mixer == "rglru":
        c["rec"] = rec.rglru_init_state(cfg, batch)
    elif mixer == "rwkv":
        c["rec"] = rec.rwkv_init_state(cfg, batch)
    if mlpk == "cmix":
        c["cmix_prev"] = rec.cmix_init_state(cfg, batch)
    return c


def init_decode_state(cfg: ModelConfig, params, batch_size: int, cache_len: int,
                      context: jax.Array | None = None) -> dict:
    """Build the decode state pytree (caches stacked to match scan groups).

    ``context``: media embeddings (VLM) or encoder output (audio); cross-attn
    K/V are precomputed here once and reused every step.
    """
    dtype = jnp.dtype(cfg.dtype)
    layers = []
    for (superblock, repeat, _) in cfg.plan_blocks():
        entries = []
        for bdef in superblock:
            c = _block_cache(cfg, bdef, batch_size, cache_len, dtype)
            if repeat > 1:
                c = jax.tree.map(lambda a: jnp.broadcast_to(a, (repeat, *a.shape)), c)
            entries.append(c)
        layers.append(tuple(entries))

    ctx_kv = None
    if context is not None:
        compute_params = jax.tree.map(
            lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
            params)
        ctx_kv = []
        for (superblock, repeat, _), gp in zip(cfg.plan_blocks(), compute_params["decoder"]):
            entries = []
            for bdef, bp in zip(superblock, gp):
                if bdef[0] != "cross":
                    entries.append(None)
                elif repeat == 1:
                    k, v = attn.media_kv(cfg, bp["mixer"], context)
                    entries.append({"k": k, "v": v})
                else:
                    k, v = jax.vmap(
                        lambda m, ctx=context: attn.media_kv(cfg, m, ctx))(bp["mixer"])
                    entries.append({"k": k, "v": v})
            ctx_kv.append(tuple(entries))
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers, "ctx_kv": ctx_kv}


def _decode_block(cfg, bdef, p, x, cache, ctx, pos, cache_len):
    mixer, mlpk = bdef
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache)
    if mixer in ("attn", "local"):
        y, kv = attn.decode_self_attention(
            cfg, p["mixer"], h, cache["kv"], pos,
            window=_attn_window(cfg, mixer, cache_len))
        new_cache["kv"] = kv
    elif mixer == "cross":
        y = attn.decode_cross_attention(cfg, p["mixer"], h, ctx)
    elif mixer == "rglru":
        y, st = rec.decode_rglru(cfg, p["mixer"], h, cache["rec"])
        new_cache["rec"] = st
    elif mixer == "rwkv":
        y, st = rec.decode_rwkv(cfg, p["mixer"], h, cache["rec"])
        new_cache["rec"] = st
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if mlpk == "mlp":
        x = x + apply_mlp(cfg, p["mlp"], h2)
    elif mlpk == "moe":
        y, _ = moe_mod.apply_moe(cfg, p["mlp"], h2)
        x = x + y
    elif mlpk == "cmix":
        shifted = token_shift(h2, cache["cmix_prev"].astype(h2.dtype))
        x = x + apply_cmix(cfg, p["mlp"], h2, shifted)
        new_cache["cmix_prev"] = h2[:, 0, :].astype(jnp.float32)
    return x, new_cache


def decode_step(cfg: ModelConfig, params: dict, state: dict, tokens: jax.Array,
                cache_len: int):
    """tokens: (B, 1) int32 -> (logits (B,1,V) f32, new_state)."""
    dtype = jnp.dtype(cfg.dtype)
    compute_params = jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        params)
    pos = state["pos"]
    x = jnp.take(compute_params["embed"], tokens, axis=0)
    if cfg.pos_embedding == "learned":
        x = x + compute_params["pos_table"][pos][None, None, :].astype(x.dtype)

    new_layers = []
    for gi, ((superblock, repeat, _), gp, gc) in enumerate(
            zip(cfg.plan_blocks(), compute_params["decoder"], state["layers"])):
        ctx_entries = state["ctx_kv"][gi] if state["ctx_kv"] is not None else [None] * len(superblock)
        if repeat == 1:
            entries = []
            for bdef, bp, bc, ctx in zip(superblock, gp, gc, ctx_entries):
                x, nc = _decode_block(cfg, bdef, bp, x, bc, ctx, pos, cache_len)
                entries.append(nc)
            new_layers.append(tuple(entries))
        elif not cfg.scan_layers:
            new_entries = [[] for _ in superblock]
            for i in range(repeat):
                for j, (bdef, bp, bc) in enumerate(zip(superblock, gp, gc)):
                    ctx = ctx_entries[j]
                    ctx_i = _unstack(ctx, i) if isinstance(ctx, dict) else None
                    x, nc = _decode_block(cfg, bdef, _unstack(bp, i), x,
                                          _unstack(bc, i), ctx_i, pos, cache_len)
                    new_entries[j].append(nc)
            stacked = tuple(
                jax.tree.map(lambda *xs: jnp.stack(xs), *entries)
                for entries in new_entries)
            new_layers.append(stacked)
        else:
            def body(xx, xs, superblock=superblock):
                bps, bcs, ctxs = xs
                ncs = []
                for bdef, bp, bc, ctx in zip(superblock, bps, bcs, ctxs):
                    xx, nc = _decode_block(cfg, bdef, bp, xx, bc, ctx, pos, cache_len)
                    ncs.append(nc)
                return xx, tuple(ncs)

            ctxs = tuple(
                c if c is not None else jnp.zeros((repeat,), dtype)
                for c in ctx_entries)
            x, new_gc = jax.lax.scan(body, x, (gp, gc, ctxs))
            new_layers.append(new_gc)
    logits = _logits(cfg, compute_params, x)
    new_state = {"pos": pos + 1, "layers": new_layers, "ctx_kv": state["ctx_kv"]}
    return logits, new_state


partial = partial  # noqa
