"""Shared primitive layers: parameter specs, norms, rope, MLPs.

Parameters are described by ``PSpec`` leaves (shape + logical axes + init
kind); the same spec tree drives real init, abstract init (dry-run) and the
logical→mesh sharding rules in ``repro.distributed.sharding``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------------
# Parameter specs


@dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]       # logical axis names, len == len(shape)
    init: str = "normal"               # normal | zeros | ones | lru_lambda
    fan_in: int | None = None          # override scale denominator

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x: Any) -> bool:
    return isinstance(x, PSpec)


def _materialize(spec: PSpec, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "lru_lambda":
        # RG-LRU: Λ s.t. a = sigmoid(Λ)^(c·r) starts with |a| in [0.9, 0.999]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(u ** (1.0 / 8.0) / (1 - u ** (1.0 / 8.0)))
        return lam.astype(dtype)
    fan_in = spec.fan_in or (spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1])
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32) * scale).astype(dtype)


def init_tree(specs, key: jax.Array, dtype) -> Any:
    """Materialize a PSpec tree into real parameters (unique key per leaf)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))
    vals = [_materialize(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(specs, dtype) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=is_pspec
    )


def axes_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_pspec)


def stack_specs(specs, n: int) -> Any:
    """Prepend a scanned 'layers' dim of size n to every leaf spec."""
    return jax.tree.map(
        lambda s: dataclasses.replace(s, shape=(n, *s.shape), axes=("layers", *s.axes)),
        specs,
        is_leaf=is_pspec,
    )


# ----------------------------------------------------------------------
# Activation sharding constraints (Megatron-style), mesh-aware and optional:
# no-ops when no mesh is active or a dim is not divisible.


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain activation sharding by logical role per dim.

    Roles: 'batch' -> ('pod','data') prefix that divides, 'model' -> the
    tensor-parallel axis, None -> replicated. Silently skips when the ambient
    mesh lacks the axis or the dim is not divisible.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return x
    if mesh is None or not mesh.axis_names:
        return x
    names = mesh.axis_names
    shape = x.shape
    spec: list = []
    for dim, role in zip(shape, axes):
        entry = None
        if role == "batch":
            chosen, size = [], 1
            for a in ("pod", "data"):
                if a in names and dim % (size * mesh.shape[a]) == 0:
                    chosen.append(a)
                    size *= mesh.shape[a]
            entry = tuple(chosen) if chosen else None
        elif role == "model" and "model" in names and dim % mesh.shape["model"] == 0:
            entry = "model"
        elif role == "data" and "data" in names and dim % mesh.shape["data"] == 0:
            entry = "data"
        spec.append(entry)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_any(x: jax.Array, *options: tuple) -> jax.Array:
    """Apply the first constraint option whose 'model'-role dims divide.

    Used where the preferred sharding can be impossible for an arch (e.g.
    56 attention heads on a 16-way model axis): fall back to
    sequence-parallel sharding instead of silently replicating O(T^2)
    buffers."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return x
    if mesh is None or not mesh.axis_names or "model" not in mesh.axis_names:
        return x
    msize = mesh.shape["model"]
    for axes in options:
        ok = True
        for dim, role in zip(x.shape, axes):
            if role == "model" and dim % msize != 0:
                ok = False
                break
        if ok:
            return constrain(x, *axes)
    return x


# ----------------------------------------------------------------------
# Norms


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def group_norm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    """Per-head GroupNorm used by RWKV time-mix output. x: (..., H, K)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_spec(d: int) -> PSpec:
    return PSpec((d,), ("embed",), init="zeros")


# ----------------------------------------------------------------------
# Rotary embeddings


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> (sin, cos) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, T, H, hd); sin/cos: (T, hd//2) or broadcastable (B, T, hd//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if sin.ndim == 2:  # (T, half) -> (1, T, 1, half)
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:  # (B, T, half) -> (B, T, 1, half)
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


# ----------------------------------------------------------------------
# MLPs


def mlp_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "wi_gate": PSpec((d, f), ("embed", "mlp")),
            "wi_up": PSpec((d, f), ("embed", "mlp")),
            "wo": PSpec((f, d), ("mlp", "embed")),
        }
    return {"wi": PSpec((d, f), ("embed", "mlp")), "wo": PSpec((f, d), ("mlp", "embed"))}


def apply_mlp(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    elif cfg.mlp_variant == "geglu":
        h = jax.nn.gelu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = constrain(h, "batch", None, "model")
    return h @ p["wo"]


def cmix_specs(cfg) -> dict:
    """RWKV channel-mix (token-shift + squared-relu FFN)."""
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": PSpec((d,), ("embed",), init="ones"),
        "mu_r": PSpec((d,), ("embed",), init="ones"),
        "wk": PSpec((d, f), ("embed", "mlp")),
        "wr": PSpec((d, d), ("embed", "embed")),
        "wv": PSpec((f, d), ("mlp", "embed")),
    }


def token_shift(x: jax.Array, x_prev_last: jax.Array | None = None) -> jax.Array:
    """Shift sequence right by one. x: (B, T, d). For decode, pass prev token."""
    if x_prev_last is not None:
        return x_prev_last[:, None, :]
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def apply_cmix(cfg, p: dict, x: jax.Array, shifted: jax.Array) -> jax.Array:
    xk = x + (shifted - x) * p["mu_k"]
    xr = x + (shifted - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])


# ----------------------------------------------------------------------
# Misc


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


def cast(tree, dtype):
    return jax.tree.map(lambda a: a.astype(dtype) if a.dtype != jnp.int32 else a, tree)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, T, C), w: (width, C), b: (C,)."""
    width = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(width):
        tap = w[i][None, None, :]
        if i == 0:
            out = out + x * tap
        else:
            out = out + jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i, :] * tap
    return out + b[None, None, :]


def conv1d_decode(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array):
    """One-step depthwise causal conv. x_t: (B, C); conv_state: (B, width-1, C)
    holding previous inputs (oldest first). Returns (y_t, new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, width, C)
    # full-seq form is out_t = sum_i w[i] * x_{t-i}; window is oldest-first,
    # so window[:, j] pairs with tap w[width-1-j].
    y = jnp.einsum("bwc,wc->bc", window, w[::-1]) + b[None, :]
    new_state = window[:, 1:, :]
    return y, new_state


partial = partial  # re-export convenience
