"""Checkpointing — the fault-tolerance contract between TonY and the ML job.

Pytrees are flattened to path-keyed npz archives; writes are atomic
(tmp + rename) so a mid-write task kill never corrupts the latest checkpoint,
which is exactly what the AM's relaunch path relies on.
"""
from __future__ import annotations

import os
import re
import tempfile

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"ckpt_(\d{8})\.npz", f))]
    return max(steps) if steps else None


def restore_pytree(template, directory: str, step: int | None = None):
    """Restore into the structure of ``template`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat = dict(data)
    keys = []
    for p, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        if tuple(flat[key].shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{flat[key].shape} vs {leaf.shape}")
        keys.append(flat[key])
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, keys)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, tree, step: int) -> str:
        path = save_pytree(tree, self.directory, step)
        self._gc()
        return path

    def restore(self, template, step: int | None = None):
        return restore_pytree(template, self.directory, step)

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        ckpts = sorted(f for f in os.listdir(self.directory)
                       if re.fullmatch(r"ckpt_\d{8}\.npz", f))
        for f in ckpts[:-self.keep]:
            os.unlink(os.path.join(self.directory, f))
