"""Checkpointing — the fault-tolerance contract between TonY and the ML job.

Pytrees are flattened to path-keyed npz archives. Each checkpoint is a
``step_<n>`` directory holding the arrays plus a ``COMMIT`` marker written
last — a step without its marker is half-written (the writer was killed
mid-checkpoint, exactly the situation the chaos harness creates on purpose)
and is invisible to ``latest_step`` / ``restore`` / garbage collection.
Directory staging + atomic rename means a mid-write kill never corrupts the
latest checkpoint, which is what the AM's ``resume_step`` relaunch path
relies on.

The pre-PR-7 flat layout (``ckpt_<n>.npz``, atomic by rename alone) is still
readable so existing checkpoint directories keep working.

``AsyncCheckpointer`` moves the npz write off the training critical path: the
caller's ``save`` only snapshots device arrays to host and hands them to a
single background writer thread (bounded, depth 1 — a second save while one
is in flight blocks, never queues unboundedly). The writer reuses the same
staged-dir + COMMIT + atomic-rename protocol, and the ``on_commit`` callback
fires *from the writer, after the rename* — so anything published off it
(``ctx.shared["ckpt_step"]``) can only ever name a committed step, keeping
the AM's ``resume_step`` contract byte-identical to the sync path.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
import time
from typing import Callable

import jax
import numpy as np

_SEP = "|"
_STEP_DIR = re.compile(r"step_(\d{8})")
_LEGACY_FILE = re.compile(r"ckpt_(\d{8})\.npz")
# re-checkpointing an existing step renames the old committed dir aside
# under this pattern before the replace; until the replace lands, the aside
# copy still counts as committed (no window where the step is lost)
_ASIDE_DIR = re.compile(r"\.aside-step_(\d{8})-.*")
COMMIT_MARKER = "COMMIT"
ARRAYS_FILE = "arrays.npz"


def _flatten(tree) -> dict[str, np.ndarray]:
    items = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        items.append((key, leaf))
    # start every device->host transfer before materializing any of them, so
    # the copies overlap instead of serializing one blocking d2h at a time
    for _, leaf in items:
        if hasattr(leaf, "copy_to_host_async"):
            try:
                leaf.copy_to_host_async()
            except Exception:  # noqa: BLE001 - committed buffers still readable
                pass
    return {key: np.asarray(leaf) for key, leaf in items}


def tree_nbytes(tree) -> int:
    """Total leaf bytes — the payload size a checkpoint of ``tree`` writes."""
    return int(sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree)))


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def _aside_dirs(directory: str, step: int) -> list[str]:
    """Committed aside copies of ``step`` (old dir renamed out of the way by
    a re-checkpoint that hasn't finished — or was killed mid-swap)."""
    try:
        entries = os.listdir(directory)
    except OSError:
        return []
    return sorted(
        os.path.join(directory, e) for e in entries
        if (m := _ASIDE_DIR.fullmatch(e)) and int(m.group(1)) == step
        and os.path.exists(os.path.join(directory, e, COMMIT_MARKER)))


def is_committed(directory: str, step: int) -> bool:
    """A step counts only once its COMMIT marker exists (or it is a legacy
    flat file, which was atomic by rename)."""
    if os.path.exists(os.path.join(step_dir(directory, step), COMMIT_MARKER)):
        return True
    if _aside_dirs(directory, step):
        return True
    return os.path.exists(os.path.join(directory, f"ckpt_{step:08d}.npz"))


def save_pytree(tree, directory: str, step: int,
                pre_commit: Callable[[], None] | None = None) -> str:
    """Write one checkpoint: stage into a tmp dir, add the COMMIT marker,
    atomically rename into place. A concurrent reader never observes a
    committed-but-incomplete step.

    Re-checkpointing an existing step never opens a lost-step window: the
    old committed dir is renamed aside (where ``latest_step``/``restore``
    still recognize it) and removed only after the replace lands — a kill at
    any point leaves either the old or the new committed copy visible.

    ``pre_commit`` (used by the chaos harness) runs after the arrays are
    staged and before the COMMIT marker is written — the writer-window kill
    point.
    """
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    final = step_dir(directory, step)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".tmp-step_{step:08d}-")
    try:
        with open(os.path.join(tmp, ARRAYS_FILE), "wb") as f:
            np.savez(f, **flat)
        if pre_commit is not None:
            pre_commit()
        with open(os.path.join(tmp, COMMIT_MARKER), "w") as f:
            json.dump({"step": step, "arrays": len(flat)}, f)
        aside = None
        if os.path.isdir(final):          # re-checkpointing the same step
            aside = os.path.join(
                directory, f".aside-step_{step:08d}-{os.urandom(4).hex()}")
            os.rename(final, aside)
        os.replace(tmp, final)
        if aside is not None:
            shutil.rmtree(aside, ignore_errors=True)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def _committed_steps(directory: str) -> list[int]:
    """All fully-written steps, tolerating junk: non-step entries, staging
    dirs and half-written (marker-less) steps are skipped, not errors.
    Committed aside copies (a re-checkpoint killed mid-swap) still count."""
    steps = set()
    try:
        entries = os.listdir(directory)
    except OSError:
        return []
    for entry in entries:
        if (m := _STEP_DIR.fullmatch(entry)):
            if os.path.exists(os.path.join(directory, entry, COMMIT_MARKER)):
                steps.add(int(m.group(1)))
        elif (m := _LEGACY_FILE.fullmatch(entry)):
            steps.add(int(m.group(1)))
        elif (m := _ASIDE_DIR.fullmatch(entry)):
            if os.path.exists(os.path.join(directory, entry, COMMIT_MARKER)):
                steps.add(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def restore_pytree(template, directory: str, step: int | None = None):
    """Restore into the structure of ``template`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(step_dir(directory, step), ARRAYS_FILE)
    if not (os.path.exists(path)
            and os.path.exists(os.path.join(step_dir(directory, step),
                                            COMMIT_MARKER))):
        # a re-checkpoint killed mid-swap leaves the old committed copy
        # aside; fall back to it, then to the legacy flat layout
        asides = _aside_dirs(directory, step)
        legacy = os.path.join(directory, f"ckpt_{step:08d}.npz")
        if asides:
            path = os.path.join(asides[-1], ARRAYS_FILE)
        elif os.path.exists(legacy):
            path = legacy
        else:
            raise FileNotFoundError(
                f"no committed checkpoint for step {step} in {directory}")
    with np.load(path) as data:
        flat = dict(data)
    keys = []
    for p, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        if tuple(flat[key].shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{flat[key].shape} vs {leaf.shape}")
        keys.append(flat[key])
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, keys)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, tree, step: int) -> str:
        path = save_pytree(tree, self.directory, step)
        self._gc()
        return path

    def restore(self, template, step: int | None = None):
        return restore_pytree(template, self.directory, step)

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def _gc(self) -> None:
        """Drop committed checkpoints beyond ``keep``, oldest first.

        Tolerates concurrent/partial state: entries that aren't ``step_*``
        (user files, staging dirs), half-written steps (no COMMIT marker)
        and races with other deleters are all skipped, never crashes.
        """
        if not os.path.isdir(self.directory):
            return
        for step in _committed_steps(self.directory)[:-self.keep]:
            victims = [step_dir(self.directory, step),
                       os.path.join(self.directory, f"ckpt_{step:08d}.npz")]
            victims += _aside_dirs(self.directory, step)
            for victim in victims:
                try:
                    if os.path.isdir(victim):
                        shutil.rmtree(victim)
                    elif os.path.exists(victim):
                        os.unlink(victim)
                except OSError:
                    pass  # lost a race with another gc/writer — fine
        # stale aside copies (re-checkpoint killed after the replace landed
        # but before cleanup) are redundant once the final dir is committed
        for step in _committed_steps(self.directory):
            if os.path.exists(os.path.join(step_dir(self.directory, step),
                                           COMMIT_MARKER)):
                for aside in _aside_dirs(self.directory, step):
                    shutil.rmtree(aside, ignore_errors=True)


class AsyncCheckpointer(Checkpointer):
    """Double-buffered checkpointing off the training critical path.

    ``save(tree, step)`` snapshots the pytree to host (overlapped d2h
    transfers) and hands the flat tree to a single background writer thread.
    The hand-off slot is depth 1: a second ``save`` while a write is in
    flight *blocks* until the writer commits — bounded memory, never an
    unbounded queue of snapshots.

    The writer reuses ``save_pytree``'s staged-dir + COMMIT + atomic-rename
    protocol and invokes ``on_commit(step, path, duration_s, nbytes)`` only
    after the rename lands — publishing ``ctx.shared["ckpt_step"]`` from
    that callback preserves the resume contract exactly: a kill mid-write
    resumes from the previous committed step.

    A writer-side failure (including a chaos kill injected via
    ``chaos_hook(step)``, which fires inside the writer window between
    staging and commit) is sticky: it re-raises from the next ``save`` or
    ``flush`` on the training thread, so the task dies and the AM's retry
    path takes over.
    """

    def __init__(self, directory: str, keep: int = 3,
                 on_commit: Callable[[int, str, float, int], None] | None = None,
                 chaos_hook: Callable[[int], None] | None = None):
        super().__init__(directory, keep)
        self.on_commit = on_commit
        self.chaos_hook = chaos_hook
        self._cond = threading.Condition()
        self._slot: tuple[dict[str, np.ndarray], int] | None = None
        self._busy = False
        self._closed = False
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._writer, daemon=True,
                                        name=f"ckpt-writer:{directory}")
        self._thread.start()

    # -- training-thread side ------------------------------------------
    def save(self, tree, step: int) -> None:
        """Snapshot now, write in the background. Blocks only while a
        previous write is still in flight (depth-1 backpressure)."""
        flat = _flatten(tree)          # host snapshot; safe to mutate tree after
        with self._cond:
            self._raise_pending_locked()
            while self._slot is not None or self._busy:
                self._cond.wait(0.05)
                self._raise_pending_locked()
            if self._closed:
                raise RuntimeError("AsyncCheckpointer is closed")
            self._slot = (flat, step)
            self._cond.notify_all()

    def flush(self) -> None:
        """Block until no write is pending or in flight; re-raise any
        deferred writer error on the calling thread."""
        with self._cond:
            while self._slot is not None or self._busy:
                self._cond.wait(0.05)
            self._raise_pending_locked()

    def close(self, timeout: float = 30.0) -> None:
        """Graceful shutdown: let the pending write (if any) commit, then
        stop the writer. Never raises — call ``flush`` first when deferred
        errors must surface."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout)

    def _raise_pending_locked(self) -> None:
        if self._error is not None:
            raise self._error

    # -- writer thread -------------------------------------------------
    def _writer(self) -> None:
        while True:
            with self._cond:
                while self._slot is None and not self._closed:
                    self._cond.wait(0.05)
                if self._slot is None:
                    return             # closed and drained
                flat, step = self._slot
                self._slot = None
                self._busy = True
                self._cond.notify_all()
            err: BaseException | None = None
            try:
                t0 = time.monotonic()
                pre = (lambda: self.chaos_hook(step)) if self.chaos_hook else None
                path = save_pytree(flat, self.directory, step, pre_commit=pre)
                self._gc()
                if self.on_commit is not None:
                    self.on_commit(step, path, time.monotonic() - t0,
                                   tree_nbytes(flat))
            except BaseException as e:  # noqa: BLE001 - deferred to caller
                err = e
            with self._cond:
                if err is not None:
                    self._error = err
                self._busy = False
                self._cond.notify_all()
