"""Checkpointing — the fault-tolerance contract between TonY and the ML job.

Pytrees are flattened to path-keyed npz archives. Each checkpoint is a
``step_<n>`` directory holding the arrays plus a ``COMMIT`` marker written
last — a step without its marker is half-written (the writer was killed
mid-checkpoint, exactly the situation the chaos harness creates on purpose)
and is invisible to ``latest_step`` / ``restore`` / garbage collection.
Directory staging + atomic rename means a mid-write kill never corrupts the
latest checkpoint, which is what the AM's ``resume_step`` relaunch path
relies on.

The pre-PR-7 flat layout (``ckpt_<n>.npz``, atomic by rename alone) is still
readable so existing checkpoint directories keep working.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import numpy as np

_SEP = "|"
_STEP_DIR = re.compile(r"step_(\d{8})")
_LEGACY_FILE = re.compile(r"ckpt_(\d{8})\.npz")
COMMIT_MARKER = "COMMIT"
ARRAYS_FILE = "arrays.npz"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def is_committed(directory: str, step: int) -> bool:
    """A step counts only once its COMMIT marker exists (or it is a legacy
    flat file, which was atomic by rename)."""
    if os.path.exists(os.path.join(step_dir(directory, step), COMMIT_MARKER)):
        return True
    return os.path.exists(os.path.join(directory, f"ckpt_{step:08d}.npz"))


def save_pytree(tree, directory: str, step: int) -> str:
    """Write one checkpoint: stage into a tmp dir, add the COMMIT marker,
    atomically rename into place. A concurrent reader never observes a
    committed-but-incomplete step."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    final = step_dir(directory, step)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".tmp-step_{step:08d}-")
    try:
        with open(os.path.join(tmp, ARRAYS_FILE), "wb") as f:
            np.savez(f, **flat)
        with open(os.path.join(tmp, COMMIT_MARKER), "w") as f:
            json.dump({"step": step, "arrays": len(flat)}, f)
        if os.path.isdir(final):          # re-checkpointing the same step
            shutil.rmtree(final)
        os.replace(tmp, final)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def _committed_steps(directory: str) -> list[int]:
    """All fully-written steps, tolerating junk: non-step entries, staging
    dirs and half-written (marker-less) steps are skipped, not errors."""
    steps = set()
    try:
        entries = os.listdir(directory)
    except OSError:
        return []
    for entry in entries:
        if (m := _STEP_DIR.fullmatch(entry)):
            if os.path.exists(os.path.join(directory, entry, COMMIT_MARKER)):
                steps.add(int(m.group(1)))
        elif (m := _LEGACY_FILE.fullmatch(entry)):
            steps.add(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def restore_pytree(template, directory: str, step: int | None = None):
    """Restore into the structure of ``template`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(step_dir(directory, step), ARRAYS_FILE)
    if not (os.path.exists(path) and is_committed(directory, step)):
        legacy = os.path.join(directory, f"ckpt_{step:08d}.npz")
        if not os.path.exists(legacy):
            raise FileNotFoundError(
                f"no committed checkpoint for step {step} in {directory}")
        path = legacy
    with np.load(path) as data:
        flat = dict(data)
    keys = []
    for p, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        if tuple(flat[key].shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{flat[key].shape} vs {leaf.shape}")
        keys.append(flat[key])
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, keys)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, tree, step: int) -> str:
        path = save_pytree(tree, self.directory, step)
        self._gc()
        return path

    def restore(self, template, step: int | None = None):
        return restore_pytree(template, self.directory, step)

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def _gc(self) -> None:
        """Drop committed checkpoints beyond ``keep``, oldest first.

        Tolerates concurrent/partial state: entries that aren't ``step_*``
        (user files, staging dirs), half-written steps (no COMMIT marker)
        and races with other deleters are all skipped, never crashes.
        """
        if not os.path.isdir(self.directory):
            return
        for step in _committed_steps(self.directory)[:-self.keep]:
            for victim in (step_dir(self.directory, step),
                           os.path.join(self.directory, f"ckpt_{step:08d}.npz")):
                try:
                    if os.path.isdir(victim):
                        shutil.rmtree(victim)
                    elif os.path.exists(victim):
                        os.unlink(victim)
                except OSError:
                    pass  # lost a race with another gc/writer — fine
