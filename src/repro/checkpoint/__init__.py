from repro.checkpoint.checkpointer import (  # noqa: F401
    AsyncCheckpointer,
    Checkpointer,
    is_committed,
    latest_step,
    restore_pytree,
    save_pytree,
    step_dir,
    tree_nbytes,
)
