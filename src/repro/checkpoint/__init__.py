from repro.checkpoint.checkpointer import (  # noqa: F401
    Checkpointer,
    is_committed,
    latest_step,
    restore_pytree,
    save_pytree,
    step_dir,
)
