"""The ML programs TonY spawns as child processes.

``make_train_program`` builds a TonY-compatible callable that runs a real JAX
training loop (model/optimizer/data/checkpointing from this repo) under
whatever cluster spec the AM hands it.

Single-process adaptation (DESIGN.md §2): the chief worker drives the
jit-compiled SPMD step over the full local mesh; other tasks execute the
launch/rendezvous/heartbeat protocol and wait — in a real multi-host
deployment every rank would call ``jax.distributed.initialize`` and drive the
same program.
"""
from __future__ import annotations

import json
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, Checkpointer, tree_nbytes
from repro.configs.base import ModelConfig
from repro.core.cluster_spec import spec_task_counts
from repro.core.task_executor import JobContext
from repro.data import PrefetchingLoader, make_dataset
from repro.distributed.steps import init_train_state, make_train_fn
from repro.launch.mesh import make_mesh_compat, set_mesh
from repro.optim import AdamWConfig


def _local_mesh(strategy: str):
    devs = np.array(jax.devices())
    n = len(devs)
    # split devices into (data, model); prefer square-ish
    model = 1
    for m in (8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return make_mesh_compat((n // model, model), ("data", "model"))


def make_train_program(cfg: ModelConfig, *, steps: int, batch_size: int,
                       seq_len: int, ckpt_dir: str, ckpt_every: int = 10,
                       strategy: str = "fsdp_tp",
                       lr: float = 1e-3,
                       data_kind: str = "synthetic",
                       data_path: str | None = None,
                       data_seed: int = 0,
                       ckpt_async: bool = True,
                       prefetch_depth: int = 2,
                       fail_at: tuple[int, int] | None = None,
                       on_step: Callable[[int, dict], None] | None = None):
    """Returns an MLProgram. ``fail_at=(attempt, step)`` injects a crash in
    the chief worker at that (attempt, step) — the fault-tolerance tests and
    benchmarks use it to exercise the AM relaunch path.

    Steady-state steps are stall-free by default: ``ckpt_async`` hands the
    checkpoint write to a background writer (``AsyncCheckpointer``) that
    publishes ``ctx.shared["ckpt_step"]`` only after commit, and
    ``prefetch_depth`` > 0 overlaps host-side batch construction with the
    accelerator step (``PrefetchingLoader``). Both degrade to the synchronous
    path (``ckpt_async=False`` / ``prefetch_depth=0``) with byte-identical
    training and resume behavior."""

    def program(env: dict[str, str], ctx: JobContext) -> int:
        task_type = env["TASK_TYPE"]
        index = int(env["TASK_INDEX"])
        task_id = f"{task_type}:{index}"
        spec = json.loads(env["CLUSTER_SPEC"])
        attempt = int(ctx.shared.get("attempt", 1))
        # a speculative backup copy joins an already-formed gang: it must
        # not touch the rendezvous barrier (the gang already passed it) and
        # keys its shared-dict entries under the copy-suffixed exec id
        speculative = env.get("SPECULATIVE") == "1"
        exec_id = task_id + "#1" if speculative else task_id

        # identify ourselves to the barrier so a chaos PARTITION window
        # blocks this endpoint's rendezvous (it can't reach its peers)
        if not speculative and not ctx.rendezvous(timeout=60.0,
                                                  exec_id=exec_id,
                                                  attempt=attempt):
            return 3  # cancelled before the job formed

        worker_types = [t for t in ("worker", "chief") if t in spec]
        chief_type = worker_types[0] if worker_types else sorted(spec)[0]
        is_chief = task_type == chief_type and index == 0

        rc = 0
        if is_chief:
            rc = _chief_train_loop(env, ctx, attempt, exec_id)
        else:
            # non-chief: stay alive for the duration of the job ("the ML
            # framework's distributed protocol" is collapsed into-process),
            # advancing its own step counter at the gang's pace through the
            # chaos-gated ctx.step hook — so a SLOW_STEP fault makes this
            # worker visibly lag the gang median (straggler detection) even
            # though only the chief runs the real training loop
            my_step = -1
            while not ctx.cancel.is_set() and not ctx.shared.get("train_done"):
                lead = max((v for k, v in ctx.progress.items()
                            if k != exec_id), default=-1)
                if my_step < lead:
                    my_step += 1
                    ctx.step(exec_id, attempt, my_step)
                else:
                    time.sleep(0.002)
            ctx.shared[f"metrics:{exec_id}"] = {
                "peak_memory_mb": 64.0, "role": 0.0}
        if not speculative:
            ctx.shared["train_done"] = True
            ctx.rendezvous(timeout=30.0, exec_id=exec_id, attempt=attempt)
        return rc

    def _chief_train_loop(env, ctx: JobContext, attempt: int, exec_id: str) -> int:
        mesh = _local_mesh(strategy)
        t_start = time.monotonic()
        # elastic resize: shard for the gang that ACTUALLY launched, not the
        # one the config asked for. A degraded attempt scales the global
        # batch down proportionally (rounded to a multiple of the mesh's
        # data axis so sharding stays valid); a full-size attempt keeps the
        # configured batch byte-for-byte.
        spec = json.loads(env["CLUSTER_SPEC"])
        counts = spec_task_counts(spec)
        targets = ctx.shared.get("target_counts") or {}
        my_type = env["TASK_TYPE"]
        n_actual = counts.get(my_type, 1)
        n_target = targets.get(my_type, n_actual)
        global_batch = batch_size
        if 0 < n_actual < n_target:
            data_ax = int(mesh.shape["data"])
            scaled = max(1, batch_size * n_actual // n_target)
            global_batch = max(data_ax, (scaled // data_ax) * data_ax)
        data = make_dataset(data_kind, global_batch, seq_len, cfg.vocab_size,
                            path=data_path, seed=data_seed)
        if prefetch_depth > 0:
            data = PrefetchingLoader(data, depth=prefetch_depth)

        def on_commit(ckpt_step: int, path: str, duration_s: float,
                      nbytes: int) -> None:
            # the resume contract's publish point: ONLY after the atomic
            # rename landed (on the async path this runs on the writer
            # thread), so the AM can never resume from an uncommitted step
            ctx.shared["ckpt_step"] = ckpt_step
            if ctx.events is not None:
                ctx.events.emit(f"ckpt:{exec_id}", "ckpt_committed",
                                step=ckpt_step, duration_s=duration_s,
                                bytes=nbytes, attempt=attempt,
                                is_async=ckpt_async)

        if ckpt_async:
            ckpt = AsyncCheckpointer(
                ckpt_dir, on_commit=on_commit,
                chaos_hook=lambda s: ctx.chaos.check_ckpt_write(
                    exec_id, attempt, s))
            # graceful teardown paths (executor exit, mid-attempt shed)
            # drain the writer so committed work is never lost
            ctx.register_flusher(ckpt.flush)
        else:
            ckpt = Checkpointer(ckpt_dir)
        with set_mesh(mesh):
            train_fn, _ = make_train_fn(
                cfg, mesh, strategy, opt=AdamWConfig(lr=lr, weight_decay=0.0))
            state = init_train_state(cfg, jax.random.PRNGKey(0))
            # checkpoint-aware recovery: prefer the AM's resume_step (the
            # deepest checkpoint a previous attempt committed), fall back to
            # whatever this directory holds (resume across submissions), and
            # only then cold-start from step 0
            start = 0
            target = ctx.shared.get("resume_step")
            if target is None:
                target = ckpt.latest_step()
            if target is not None:
                try:
                    state = ckpt.restore(state, int(target))
                except (FileNotFoundError, KeyError, ValueError, OSError):
                    target = ckpt.latest_step()
                    if target is not None:
                        state = ckpt.restore(state, int(target))
            if target is not None:
                data.load_state_dict({"step": int(target)})
                start = int(target)
                ctx.shared["ckpt_step"] = start
                ctx.shared.setdefault("restarts", []).append(
                    {"attempt": attempt, "restored_step": start})

            losses = ctx.shared.setdefault("loss_history", [])
            try:
                for step in range(start, steps):
                    if ctx.cancel.is_set():
                        return 143
                    # records progress for straggler detection + runs the
                    # chaos hooks (which may delay or kill this step)
                    ctx.step(exec_id, attempt, step)
                    if fail_at is not None and (attempt, step) == fail_at:
                        raise RuntimeError(
                            f"injected transient failure at attempt={attempt} step={step}")
                    batch = {k: jnp.asarray(v)
                             for k, v in data.next_batch().items()}
                    state, metrics = train_fn(state, batch)
                    loss = float(metrics["loss"])
                    losses.append((step, loss))
                    if on_step:
                        on_step(step, {k: float(v) for k, v in metrics.items()})
                    if (step + 1) % ckpt_every == 0 or step + 1 == steps:
                        if ckpt_async:
                            # snapshot + hand off; the writer publishes
                            # ckpt_step after commit. A deferred writer error
                            # (e.g. a chaos kill mid-write) re-raises here.
                            ckpt.save(state, step + 1)
                        else:
                            t0 = time.monotonic()
                            path = ckpt.save(
                                jax.tree.map(np.asarray, state), step + 1)
                            on_commit(step + 1, path, time.monotonic() - t0,
                                      tree_nbytes(state))
                if ckpt_async:
                    # normal exit: surface any deferred writer error and make
                    # sure the final checkpoint committed before succeeding
                    ckpt.flush()
            finally:
                if ckpt_async:
                    ckpt.close()
                if prefetch_depth > 0:
                    data.close()
            ctx.shared[f"metrics:{exec_id}"] = {
                "peak_memory_mb": float(
                    sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
                    / 1e6),
                "steps": float(steps),
                "final_loss": losses[-1][1] if losses else float("nan"),
                "train_seconds": time.monotonic() - t_start,
                "world_size": float(sum(counts.values())),
                "global_batch": float(global_batch),
            }
        return 0

    return program
