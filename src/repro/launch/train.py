"""Training driver: submits a distributed training job through the full TonY
path (client -> RM -> AM -> executors -> JAX train loop).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --batch-size 8 --seq-len 64 [--workers 2 --ps 1]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import (
    EventLog,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    JobHistoryServer,
    MetricsAnalyzer,
    NodeHealthTracker,
    SpeculationPolicy,
    TonYClient,
    YarnLikeBackend,
    format_failure_report,
    job_spec_from_props,
    make_cluster,
)
from repro.launch.programs import make_train_program


def build_job(name: str, workers: int, ps: int, gpus_per_worker: int = 1,
              min_workers: int = 0):
    props = {
        "tony.application.name": name,
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "8192",
        "tony.worker.vcores": "4",
        "tony.worker.gpus": str(gpus_per_worker),
        "tony.worker.node-label": "gpu",
    }
    if min_workers > 0:
        # elastic gang: the AM may run degraded down to this many workers
        props["tony.worker.min-instances"] = str(min_workers)
    if ps > 0:
        props.update({
            "tony.ps.instances": str(ps),
            "tony.ps.memory": "4096",
            "tony.ps.vcores": "2",
            "tony.ps.node-label": "highmem",
        })
    return job_spec_from_props(props)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tony-paper-mlp", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--min-workers", type=int, default=0,
                    help="elastic gang floor (tony.worker.min-instances); "
                         "0 = rigid: exactly --workers or the attempt fails")
    ap.add_argument("--ps", type=int, default=1)
    ap.add_argument("--strategy", default="fsdp_tp")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--ckpt-async", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="write checkpoints from a background writer thread "
                         "(publishes ckpt_step only after commit); "
                         "--no-ckpt-async restores the blocking writer")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="batches the data pipeline builds ahead of the "
                         "train step (0 = synchronous batch construction)")
    chaos = ap.add_argument_group(
        "chaos", "deterministic fault injection (core/chaos.py)")
    chaos.add_argument("--chaos-seed", type=int, default=1234,
                       help="seed identifying the fault plan in events/logs")
    chaos.add_argument("--chaos-kill-step", type=int, default=None,
                       help="kill the chief worker at this step (once)")
    chaos.add_argument("--chaos-oom-step", type=int, default=None,
                       help="OOM the chief worker at this step (once)")
    chaos.add_argument("--chaos-kill-ckpt-write", type=int, default=None,
                       metavar="STEP",
                       help="kill the chief inside the async checkpoint "
                            "writer while it writes this step (once) — the "
                            "relaunch must resume from the previous "
                            "committed step")
    chaos.add_argument("--chaos-random-faults", type=int, default=0,
                       help="generate N seeded random kill/OOM faults")
    chaos.add_argument("--blacklist-threshold", type=int, default=3,
                       help="INFRA failures on one node before blacklisting")
    chaos.add_argument("--chaos-slow-task", default=None, metavar="TASK",
                       help="inject a straggler: slow this task's steps "
                            "(e.g. worker:1)")
    chaos.add_argument("--chaos-slow-step", type=int, default=0,
                       help="first slowed step (with --chaos-slow-task)")
    chaos.add_argument("--chaos-slow-until", type=int, default=None,
                       help="last slowed step (default: every step onward)")
    chaos.add_argument("--chaos-slow-delay", type=float, default=0.05,
                       help="extra seconds added to each slowed step")
    chaos.add_argument("--chaos-partition-src", default=None, metavar="TASK",
                       help="partition: one endpoint pattern (e.g. worker:0)")
    chaos.add_argument("--chaos-partition-dst", default="*", metavar="TASK",
                       help="partition: the other endpoint pattern")
    chaos.add_argument("--chaos-partition-step", type=int, default=None,
                       help="step-gated partition: first affected step "
                            "(raises from the src side)")
    chaos.add_argument("--chaos-partition-until", type=int, default=None,
                       help="step-gated partition: last affected step "
                            "(default: only --chaos-partition-step)")
    chaos.add_argument("--chaos-partition-after", type=float, default=0.0,
                       help="time-gated partition: seconds after task start")
    chaos.add_argument("--chaos-partition-duration", type=float, default=0.0,
                       help="time-gated partition: window length in seconds "
                            "(heartbeats dropped, rendezvous blocked)")
    spec = ap.add_argument_group(
        "speculation", "straggler detection + backups (core/speculation.py)")
    spec.add_argument("--speculation", action="store_true",
                      help="enable speculative execution for stragglers")
    spec.add_argument("--speculation-factor", type=float, default=2.0,
                      help="lagging iff progress * factor < gang median")
    spec.add_argument("--speculation-patience", type=int, default=5,
                      help="consecutive lagging observations before a backup")
    spec.add_argument("--speculation-min-progress", type=int, default=4,
                      help="gang median step before detection arms")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="tony-train-")

    plan = FaultPlan(seed=args.chaos_seed)
    if args.chaos_kill_step is not None:
        plan = plan.add(FaultSpec(FaultKind.KILL_TASK, task="worker:0",
                                  at_step=args.chaos_kill_step))
    if args.chaos_oom_step is not None:
        plan = plan.add(FaultSpec(FaultKind.OOM, task="worker:0",
                                  at_step=args.chaos_oom_step))
    if args.chaos_kill_ckpt_write is not None:
        plan = plan.add(FaultSpec(FaultKind.KILL_TASK, task="worker:0",
                                  at_step=args.chaos_kill_ckpt_write,
                                  in_ckpt_write=True))
    if args.chaos_random_faults:
        plan = FaultPlan(plan.seed, plan.faults + FaultPlan.random_plan(
            args.chaos_seed, steps=args.steps,
            n_faults=args.chaos_random_faults).faults)
    if args.chaos_slow_task:
        plan = plan.add(FaultSpec(FaultKind.SLOW_STEP, task=args.chaos_slow_task,
                                  at_step=args.chaos_slow_step,
                                  until_step=args.chaos_slow_until,
                                  delay_s=args.chaos_slow_delay))
    if args.chaos_partition_src:
        plan = plan.add(FaultSpec(FaultKind.PARTITION,
                                  src=args.chaos_partition_src,
                                  dst=args.chaos_partition_dst,
                                  at_step=args.chaos_partition_step,
                                  until_step=args.chaos_partition_until,
                                  after_s=args.chaos_partition_after,
                                  duration_s=args.chaos_partition_duration))

    events = EventLog()
    rm = make_cluster(num_gpu_nodes=4, num_cpu_nodes=2, gpus_per_node=4,
                      event_log=events,
                      chaos=FaultInjector(plan, events=events),
                      health=NodeHealthTracker(
                          threshold=args.blacklist_threshold, events=events))
    speculation = SpeculationPolicy(
        enabled=args.speculation,
        slowdown_factor=args.speculation_factor,
        patience=args.speculation_patience,
        min_progress=args.speculation_min_progress)
    client = TonYClient(YarnLikeBackend(rm, speculation=speculation))
    job = build_job(f"train-{cfg.name}", args.workers, args.ps,
                    min_workers=args.min_workers)

    steps_log = []
    prog = make_train_program(
        cfg, steps=args.steps, batch_size=args.batch_size, seq_len=args.seq_len,
        ckpt_dir=os.path.join(ckpt_dir, "ckpt"), ckpt_every=args.ckpt_every,
        ckpt_async=args.ckpt_async, prefetch_depth=args.prefetch_depth,
        strategy=args.strategy, lr=args.lr,
        on_step=lambda s, m: steps_log.append((s, m["loss"])))

    result = client.run_and_wait(job, prog)
    history = JobHistoryServer()
    history.record(job, result)
    summary = history.summary(result.app_id)
    print(json.dumps({
        "status": result.final_status,
        "attempts": len(result.attempts),
        "ui_url": result.ui_url,
        "first_loss": steps_log[0][1] if steps_log else None,
        "final_loss": steps_log[-1][1] if steps_log else None,
        "suggestions": [s.message for s in MetricsAnalyzer().analyze(job, result)],
        "failure_reasons": summary["failure_reasons"],
        "retry_advice": summary["retry_advice"],
        "resumed_attempts": summary["resumed_attempts"],
        "resized_attempts": summary["resized_attempts"],
        "blacklisted_nodes": summary["blacklisted_nodes"],
        "stragglers": summary["stragglers"],
        "speculation": summary["speculation"],
        "chaos_injected": events.count("chaos_injected"),
        "ckpt_committed": events.count("ckpt_committed"),
        "ckpt_dir": ckpt_dir,
    }, indent=2))
    if not result.succeeded:
        print(format_failure_report(result))


if __name__ == "__main__":
    main()
