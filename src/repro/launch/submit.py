"""TonY client CLI: submit a job described by a tony.xml file.

  PYTHONPATH=src python -m repro.launch.submit --xml job.xml \
      [--arch qwen3-1.7b --smoke --steps 20]

The XML's task types/resources drive the cluster negotiation; --arch picks
the ML program the executors spawn.
"""
from __future__ import annotations

import argparse
import json
import tempfile

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import TonYClient, YarnLikeBackend, make_cluster, parse_tony_xml
from repro.launch.programs import make_train_program


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--xml", required=True)
    ap.add_argument("--arch", default="tony-paper-mlp", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    job = parse_tony_xml(args.xml)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rm = make_cluster(num_gpu_nodes=4, num_cpu_nodes=2, gpus_per_node=4)
    client = TonYClient(YarnLikeBackend(rm))
    prog = make_train_program(cfg, steps=args.steps, batch_size=args.batch_size,
                              seq_len=args.seq_len,
                              ckpt_dir=tempfile.mkdtemp(prefix="tony-submit-"))
    result = client.run_and_wait(job, prog)
    print(json.dumps({
        "app_id": result.app_id,
        "status": result.final_status,
        "attempts": len(result.attempts),
        "ui_url": result.ui_url,
        "task_logs": sorted(result.task_logs),
    }, indent=2))


if __name__ == "__main__":
    main()
