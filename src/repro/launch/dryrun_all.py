"""Run the full baseline dry-run matrix as subprocesses (fresh XLA state per
run) and collect JSON results under experiments/dryrun/.

  PYTHONPATH=src python -m repro.launch.dryrun_all [--only-mode compile]
      [--outdir experiments/dryrun] [--timeout 1800]

Matrix: 10 assigned archs x 4 shapes x {compile@16x16, compile@2x16x16,
analysis@16x16}, skips per DESIGN.md recorded as JSON too.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config

MATRIX_ARCHS = [a for a in ARCH_IDS if a != "tony-paper-mlp"]


def planned_runs(only_mode: str | None = None) -> list[dict]:
    order = sorted(MATRIX_ARCHS, key=lambda a: get_config(a).param_count())
    runs = []
    for arch in order:
        for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            for mode, multi in [("compile", False), ("compile", True),
                                ("analysis", False)]:
                if only_mode and mode != only_mode:
                    continue
                runs.append({"arch": arch, "shape": shape, "mode": mode,
                             "multi_pod": multi})
    return runs


def run_name(r: dict) -> str:
    mesh = "2x16x16" if r["multi_pod"] else "16x16"
    return f"{r['arch']}__{r['shape']}__{mesh}__{r['mode']}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--only-mode", default="")
    ap.add_argument("--strategy", default="fsdp_tp")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    runs = planned_runs(args.only_mode or None)
    t_start = time.time()
    done = 0
    for r in runs:
        name = run_name(r)
        out = os.path.join(args.outdir, name + ".json")
        if os.path.exists(out):
            done += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", r["arch"], "--shape", r["shape"],
               "--mode", r["mode"], "--strategy", args.strategy,
               "--out", out]
        if r["multi_pod"]:
            cmd.append("--multi-pod")
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout)
            ok = proc.returncode == 0 and os.path.exists(out)
            if not ok:
                with open(out, "w") as f:
                    json.dump({"arch": r["arch"], "shape": r["shape"],
                               "mode": r["mode"],
                               "mesh": "2x16x16" if r["multi_pod"] else "16x16",
                               "ok": False,
                               "error": f"rc={proc.returncode}",
                               "stderr": proc.stderr[-3000:]}, f, indent=2)
        except subprocess.TimeoutExpired:
            with open(out, "w") as f:
                json.dump({"arch": r["arch"], "shape": r["shape"],
                           "mode": r["mode"],
                           "mesh": "2x16x16" if r["multi_pod"] else "16x16",
                           "ok": False, "error": "timeout"}, f, indent=2)
        done += 1
        status = json.load(open(out)).get("ok")
        print(f"[{done}/{len(runs)}] {name}: ok={status} "
              f"({time.time()-t0:.0f}s, total {time.time()-t_start:.0f}s)",
              flush=True)


if __name__ == "__main__":
    main()
