"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The single-pod production mesh is 16x16 = 256
chips (one TPU v5e pod); multi-pod is 2x16x16 = 512 chips with a leading
'pod' axis (DCN boundary).

JAX version compatibility: ``jax.sharding.AxisType`` / the ``axis_types=``
kwarg of ``jax.make_mesh`` and the ambient-mesh context ``jax.set_mesh``
only exist on newer JAX releases. ``make_mesh_compat`` / ``set_mesh`` below
use them when present and degrade gracefully otherwise (all sharding in this
repo is explicit ``NamedSharding``, so the ambient mesh is advisory) — the
supported floor is the installed JAX (0.4.x).
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_mesh_compat(shape: Sequence[int],
                     axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across JAX versions: request explicit Auto axis
    types where the API supports them, plain mesh otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes),
                axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` on new JAX,
    the Mesh's own context manager on older releases."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(model_parallel: int | None = None) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (smoke tests, examples)."""
    n = len(jax.devices())
    if model_parallel is None:
        model_parallel = 1
        for m in (4, 2, 1):
            if n % m == 0:
                model_parallel = m
                break
    return make_mesh_compat((n // model_parallel, model_parallel),
                            ("data", "model"))


# Hardware constants (TPU v5e target) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
