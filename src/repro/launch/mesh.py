"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The single-pod production mesh is 16x16 = 256
chips (one TPU v5e pod); multi-pod is 2x16x16 = 512 chips with a leading
'pod' axis (DCN boundary).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel: int | None = None) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (smoke tests, examples)."""
    n = len(jax.devices())
    if model_parallel is None:
        model_parallel = 1
        for m in (4, 2, 1):
            if n % m == 0:
                model_parallel = m
                break
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# Hardware constants (TPU v5e target) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
