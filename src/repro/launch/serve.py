"""Serving driver: batched autoregressive decoding behind the TonY job path
(the inference-job flavour of the paper's orchestration).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 8 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import TonYClient, YarnLikeBackend, job_spec_from_props, make_cluster
from repro.launch.mesh import make_local_mesh
from repro.models import model as M


def batched_generate(cfg, params, prompts: np.ndarray, gen_len: int,
                     cache_len: int, context=None) -> tuple[np.ndarray, dict]:
    """Greedy decode: prefill via teacher-forced decode steps, then generate."""
    B, P = prompts.shape
    state = M.init_decode_state(cfg, params, B, cache_len, context=context)
    step = jax.jit(lambda p, s, t: M.decode_step(cfg, p, s, t, cache_len))
    toks = jnp.asarray(prompts)
    t0 = time.monotonic()
    logits = None
    for i in range(P):
        logits, state = step(params, state, toks[:, i:i + 1])
    out = []
    cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    for _ in range(gen_len):
        out.append(cur)
        logits, state = step(params, state, cur)
        cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    dt = time.monotonic() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    stats = {"tokens_generated": int(B * gen_len),
             "wall_s": dt,
             "tok_per_s": B * (P + gen_len) / dt}
    return gen, stats


def make_serve_program(cfg, *, batch: int, prompt_len: int, gen_len: int,
                       cache_len: int, out_box: dict):
    def program(env, ctx):
        if not ctx.rendezvous(timeout=60.0):
            return 3
        if env["TASK_TYPE"] == "worker" and env["TASK_INDEX"] == "0":
            rng = np.random.default_rng(0)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            prompts = rng.integers(0, cfg.vocab_size, size=(batch, prompt_len))
            context = None
            if cfg.uses_media or cfg.is_encoder_decoder:
                media = jnp.asarray(rng.normal(
                    size=(batch, cfg.num_media_tokens, cfg.d_model)),
                    jnp.dtype(cfg.dtype))
                context = (M.encode(cfg, params, media)
                           if cfg.is_encoder_decoder else media)
            gen, stats = batched_generate(cfg, params, prompts, gen_len,
                                          cache_len, context)
            out_box["gen"] = gen
            out_box["stats"] = stats
            ctx.shared["train_done"] = True
        else:
            while not ctx.cancel.is_set() and not ctx.shared.get("train_done"):
                time.sleep(0.005)
        ctx.rendezvous(timeout=30.0)
        return 0

    return program


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cache_len = args.prompt_len + args.gen
    rm = make_cluster(num_gpu_nodes=2, num_cpu_nodes=1, gpus_per_node=4)
    client = TonYClient(YarnLikeBackend(rm))
    job = job_spec_from_props({
        "tony.application.name": f"serve-{cfg.name}",
        "tony.worker.instances": "2",
        "tony.worker.memory": "8192",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })
    box: dict = {}
    result = client.run_and_wait(
        job, make_serve_program(cfg, batch=args.batch, prompt_len=args.prompt_len,
                                gen_len=args.gen, cache_len=cache_len,
                                out_box=box))
    print(json.dumps({"status": result.final_status,
                      "stats": box.get("stats"),
                      "sample_tokens": box["gen"][0][:8].tolist()
                      if "gen" in box else None}, indent=2))


if __name__ == "__main__":
    main()
