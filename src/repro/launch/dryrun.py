import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For one (arch x input-shape x mesh x strategy):
  compile  - lower + compile the FULL config (scan-over-layers), print
             memory_analysis (fits?) and cost_analysis, parse collective
             bytes from optimized HLO.
  analysis - lower UNROLLED reduced-depth variants (1x and 2x the block
             pattern) on the same mesh/shardings and extrapolate exact
             per-layer FLOPs/bytes/collective-bytes to full depth (XLA's
             cost_analysis counts while-loop bodies once, so the scanned
             program under-reports; see EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k \
      [--multi-pod] [--strategy fsdp_tp] [--mode compile|analysis] [--out f.json]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import steps as S
from repro.launch.mesh import make_production_mesh, set_mesh

from repro.launch.dryrun_lib import (  # noqa: E402
    COLLECTIVE_OPS,
    _extrapolate,
    _finalize_terms,
    model_flops,
    parse_collective_bytes,
    rwkv_correction_flops,
    should_skip,
)

# ----------------------------------------------------------------------


def _lower_one(cfg: ModelConfig, shape: ShapeConfig, mesh, strategy: str):
    """Lower + compile one step; returns (compiled, lowered)."""
    with set_mesh(mesh):
        if shape.kind == "train":
            fn, _ = S.make_train_fn(cfg, mesh, strategy, shape=shape)
            lowered = fn.lower(S.abstract_train_state(cfg),
                               S.train_batch_specs(cfg, shape))
        elif shape.kind == "prefill":
            fn, _ = S.make_prefill_fn(cfg, mesh, strategy, shape=shape)
            from repro.models import abstract_params
            lowered = fn.lower(abstract_params(cfg),
                               S.prefill_batch_specs(cfg, shape))
        else:
            fn, _ = S.make_decode_fn(cfg, mesh, strategy, shape=shape)
            from repro.models import abstract_params
            lowered = fn.lower(abstract_params(cfg),
                               S.decode_state_specs(cfg, shape),
                               S.decode_token_specs(shape))
        compiled = lowered.compile()
    return compiled, lowered


def _extract(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": None if ma is None else {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
        },
    }


def _coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               strategy: str = "fsdp_tp", mode: str = "compile",
               overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = INPUT_SHAPES[shape_name]
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "strategy": strategy, "mode": mode,
              "overrides": overrides or {},
              "model_flops": model_flops(cfg, shape),
              "active_params": cfg.active_param_count(),
              "total_params": cfg.param_count()}
    skip = should_skip(cfg, shape)
    if skip:
        result.update(ok=True, skipped=skip)
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        if mode == "compile":
            compiled, _ = _lower_one(cfg, shape, mesh, strategy)
            result["full"] = _extract(compiled)
            result["note"] = ("scan-over-layers program: cost_analysis counts "
                              "loop bodies once; use analysis mode for exact "
                              "roofline terms")
        else:
            pat = len(cfg.block_pattern)
            enc = cfg.encoder_layers
            if cfg.num_layers <= 12:
                c_ex = cfg.replace(scan_layers=False)
                compiled, _ = _lower_one(c_ex, shape, mesh, strategy)
                ex = _extract(compiled)
                ex["exact"] = True
                result["extrapolated"] = _finalize_terms(ex, cfg, shape)
                result["samples"] = {"exact": ex}
            else:
                c1 = cfg.replace(num_layers=pat, scan_layers=False)
                c2 = cfg.replace(num_layers=2 * pat, scan_layers=False)
                e1 = _extract(_lower_one(c1, shape, mesh, strategy)[0])
                e2 = _extract(_lower_one(c2, shape, mesh, strategy)[0])
                reps = cfg.num_layers / pat
                ext = _extrapolate(e1, e2, reps)
                result["extrapolated"] = _finalize_terms(ext, cfg, shape)
                result["samples"] = {"x1": e1, "x2": e2, "reps": reps}
        result["ok"] = True
        result["elapsed_s"] = time.time() - t0
    except Exception as e:  # noqa: BLE001
        result.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:],
                      elapsed_s=time.time() - t0)
    return result




def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="fsdp_tp")
    ap.add_argument("--mode", default="compile", choices=["compile", "analysis"])
    ap.add_argument("--out", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable), e.g. "
                         "--set fused_softmax=false --set remat=false")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = _coerce(v)
    res = run_dryrun(args.arch, args.shape, multi_pod=args.multi_pod,
                     strategy=args.strategy, mode=args.mode,
                     overrides=overrides or None)
    text = json.dumps(res, indent=2, default=str)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
