"""Pure helpers for the dry-run: HLO collective parsing, analytic FLOP
models, skip rules, extrapolation. NO jax device-state side effects —
import-safe from tests and benchmarks (unlike repro.launch.dryrun, whose
first two lines force 512 placeholder devices)."""
from __future__ import annotations

import re

from repro.configs.base import ModelConfig, ShapeConfig

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def should_skip(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention learned-position arch with no sub-quadratic "
                "variant (DESIGN.md §Shape skips)")
    return None


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out = {op: 0.0 for op in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+(.*?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\(",
                      stripped)
        if not m:
            continue
        if re.search(r"\b(" + "|".join(COLLECTIVE_OPS) + r")-done\(", stripped):
            continue  # counted at -start
        result_types, op = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(result_types):
            if dt not in _DTYPE_BYTES:
                continue
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            nbytes += size * _DTYPE_BYTES[dt]
        out[op] += nbytes
        out["count"] += 1
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs per step (6*N_active*D train, 2*N_active*D fwd)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def rwkv_correction_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The WKV time scan lowers to a while loop whose body XLA counts once;
    add its analytic FLOPs (6 ops per (K x K) state element per step)."""
    if cfg.arch_type != "ssm":
        return 0.0
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    per_tok = 6.0 * H * K * K * cfg.num_layers
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd
    return per_tok * tokens * mult


def _extrapolate(e1: dict, e2: dict, reps: float) -> dict:
    def ext(a, b):
        marg = b - a
        if marg < 0:  # fusion nondeterminism; fall back to proportional scaling
            return b * reps / 2.0
        fixed = max(a - marg, 0.0)
        return fixed + reps * marg

    out = {
        "flops": ext(e1["flops"], e2["flops"]),
        "bytes_accessed": ext(e1["bytes_accessed"], e2["bytes_accessed"]),
        "collectives": {},
        "memory": e2["memory"],
    }
    for k in COLLECTIVE_OPS:
        out["collectives"][k] = ext(e1["collectives"][k], e2["collectives"][k])
    out["collectives"]["count"] = e2["collectives"]["count"]
    return out


def _finalize_terms(ex: dict, cfg: ModelConfig, shape: ShapeConfig) -> dict:
    ex = dict(ex)
    corr = rwkv_correction_flops(cfg, shape)
    if corr:
        ex["flops_wkv_correction"] = corr
        ex["flops"] = ex["flops"] + corr
    ex["collective_bytes_total"] = sum(
        v for k, v in ex["collectives"].items() if k != "count")
    return ex
