"""Token data pipeline.

Two sources behind one interface:
  - SyntheticLMDataset: deterministic learnable sequences (a mixture of
    repeated n-gram motifs + noise) generated from (seed, step) — so training
    is reproducible, restart-safe (stateless in step) and the loss actually
    decreases.
  - FileTokenDataset: memmap-backed binary token file, the production path.

Batches are full *global* batches; sharding happens when the train step
consumes them (jit in_shardings). ``state_dict``/``load_state_dict`` make the
iterator checkpointable alongside the model, which the TonY fault-tolerance
path exercises.

``PrefetchingLoader`` wraps any source: a background thread builds up to
``depth`` batches ahead via the stateless ``batch_at(step)``, so host-side
batch construction overlaps the accelerator step instead of stalling it.
Because production is keyed on step (never on ambient iterator state), a
restore through the same ``state_dict`` contract is batch-for-batch
identical to the synchronous loader.
"""
from __future__ import annotations

import os
import threading
from collections import deque

import numpy as np


class _Base:
    def __init__(self, batch_size: int, seq_len: int):
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.step = 0

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])

    def next_batch(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def batch_at(self, step: int) -> dict:
        raise NotImplementedError


class SyntheticLMDataset(_Base):
    """Learnable synthetic LM data: each sequence interleaves one of K motif
    n-grams (deterministic structure a model can learn) with uniform noise."""

    def __init__(self, batch_size: int, seq_len: int, vocab_size: int,
                 seed: int = 0, num_motifs: int = 32, motif_len: int = 8,
                 noise_prob: float = 0.1):
        super().__init__(batch_size, seq_len)
        self.vocab_size = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.motifs = rng.integers(0, vocab_size,
                                   size=(num_motifs, motif_len)).astype(np.int32)
        self.noise_prob = noise_prob

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, T = self.batch_size, self.seq_len
        m_idx = rng.integers(0, len(self.motifs), size=(B,))
        mlen = self.motifs.shape[1]
        reps = T // mlen + 2
        # one tile of the whole motif bank + one gather, instead of a Python
        # loop per sequence (identical output: row i of the tiled bank IS
        # np.tile(motifs[i], reps))
        seqs = np.tile(self.motifs, (1, reps))[:, :T + 1][m_idx]
        noise_mask = rng.random((B, T + 1)) < self.noise_prob
        noise = rng.integers(0, self.vocab_size, size=(B, T + 1))
        seqs = np.where(noise_mask, noise, seqs).astype(np.int32)
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


class FileTokenDataset(_Base):
    """Sequential batches from a flat int32 token file (np.memmap)."""

    def __init__(self, path: str, batch_size: int, seq_len: int):
        super().__init__(batch_size, seq_len)
        self.path = path
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.tokens_per_batch = batch_size * (seq_len + 1)
        if len(self.tokens) < self.tokens_per_batch:
            raise ValueError(f"{path} too small for one batch")

    def batch_at(self, step: int) -> dict:
        n = len(self.tokens) - self.tokens_per_batch
        off = (step * self.tokens_per_batch) % max(n, 1)
        # exactly one copy out of the memmap (the file is already int32);
        # tokens/labels are views of that copy, never memmap-backed
        chunk = np.array(self.tokens[off:off + self.tokens_per_batch],
                         dtype=np.int32)
        chunk = chunk.reshape(self.batch_size, self.seq_len + 1)
        return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}

    @staticmethod
    def write_corpus(path: str, tokens: np.ndarray) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.asarray(tokens, dtype=np.int32).tofile(path)


class PrefetchingLoader:
    """Background-thread prefetch over any ``_Base`` dataset.

    A producer thread builds batches via ``dataset.batch_at(step)`` up to
    ``depth`` ahead of the consumer; ``next_batch`` then only pops a
    ready-made batch. Checkpointing goes through the same
    ``state_dict``/``load_state_dict`` contract — the state is the next step
    to be *consumed*, so a save/restore round-trip yields exactly the batch
    sequence the synchronous loader would have produced.
    """

    def __init__(self, dataset: _Base, depth: int = 2):
        self.dataset = dataset
        self.depth = max(1, int(depth))
        self.batch_size = dataset.batch_size
        self.seq_len = dataset.seq_len
        self._cond = threading.Condition()
        self._buf: deque[tuple[int, dict]] = deque()
        self._next_produce = dataset.step
        self._next_consume = dataset.step
        self._gen = 0                  # bumped on seek: stale batches dropped
        self._closed = False
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._producer, daemon=True,
                                        name="prefetch-loader")
        self._thread.start()

    # -- consumer side -------------------------------------------------
    @property
    def step(self) -> int:
        return self._next_consume

    @step.setter
    def step(self, value: int) -> None:
        if value != self._next_consume:
            self.load_state_dict({"step": int(value)})

    def state_dict(self) -> dict:
        return {"step": self._next_consume}

    def load_state_dict(self, d: dict) -> None:
        """Seek: drop everything prefetched and restart production at the
        restored step — restores are batch-for-batch identical to sync."""
        step = int(d["step"])
        with self._cond:
            self._gen += 1
            self._buf.clear()
            self._next_produce = step
            self._next_consume = step
            self._error = None
            self.dataset.load_state_dict({"step": step})
            self._cond.notify_all()

    def next_batch(self) -> dict:
        with self._cond:
            while not self._buf:
                if self._error is not None:
                    raise self._error
                if self._closed:
                    raise RuntimeError("PrefetchingLoader is closed")
                self._cond.wait(0.05)
            step, batch = self._buf.popleft()
            assert step == self._next_consume, "prefetch order violated"
            self._next_consume = step + 1
            self._cond.notify_all()
            return batch

    def batch_at(self, step: int) -> dict:
        return self.dataset.batch_at(step)

    def close(self, timeout: float = 5.0) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout)

    # -- producer thread -----------------------------------------------
    def _producer(self) -> None:
        while True:
            with self._cond:
                while len(self._buf) >= self.depth and not self._closed:
                    self._cond.wait(0.05)
                if self._closed:
                    return
                gen, step = self._gen, self._next_produce
            try:
                batch = self.dataset.batch_at(step)
                err = None
            except BaseException as e:  # noqa: BLE001 - handed to consumer
                batch, err = None, e
            with self._cond:
                if self._closed:
                    return
                if gen != self._gen:
                    continue           # seeked while producing: drop it
                if err is not None:
                    self._error = err
                    self._cond.notify_all()
                    return
                self._buf.append((step, batch))
                self._next_produce = step + 1
                self._cond.notify_all()


def make_dataset(kind: str, batch_size: int, seq_len: int, vocab_size: int,
                 path: str | None = None, seed: int = 0) -> _Base:
    if kind == "synthetic":
        return SyntheticLMDataset(batch_size, seq_len, vocab_size, seed)
    if kind == "file":
        assert path
        return FileTokenDataset(path, batch_size, seq_len)
    raise ValueError(kind)
