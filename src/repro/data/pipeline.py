"""Token data pipeline.

Two sources behind one interface:
  - SyntheticLMDataset: deterministic learnable sequences (a mixture of
    repeated n-gram motifs + noise) generated from (seed, step) — so training
    is reproducible, restart-safe (stateless in step) and the loss actually
    decreases.
  - FileTokenDataset: memmap-backed binary token file, the production path.

Batches are full *global* batches; sharding happens when the train step
consumes them (jit in_shardings). ``state_dict``/``load_state_dict`` make the
iterator checkpointable alongside the model, which the TonY fault-tolerance
path exercises.
"""
from __future__ import annotations

import os

import numpy as np


class _Base:
    def __init__(self, batch_size: int, seq_len: int):
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.step = 0

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])

    def next_batch(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def batch_at(self, step: int) -> dict:
        raise NotImplementedError


class SyntheticLMDataset(_Base):
    """Learnable synthetic LM data: each sequence interleaves one of K motif
    n-grams (deterministic structure a model can learn) with uniform noise."""

    def __init__(self, batch_size: int, seq_len: int, vocab_size: int,
                 seed: int = 0, num_motifs: int = 32, motif_len: int = 8,
                 noise_prob: float = 0.1):
        super().__init__(batch_size, seq_len)
        self.vocab_size = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.motifs = rng.integers(0, vocab_size,
                                   size=(num_motifs, motif_len)).astype(np.int32)
        self.noise_prob = noise_prob

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, T = self.batch_size, self.seq_len
        m_idx = rng.integers(0, len(self.motifs), size=(B,))
        mlen = self.motifs.shape[1]
        reps = T // mlen + 2
        seqs = np.stack([np.tile(self.motifs[i], reps)[:T + 1] for i in m_idx])
        noise_mask = rng.random((B, T + 1)) < self.noise_prob
        noise = rng.integers(0, self.vocab_size, size=(B, T + 1))
        seqs = np.where(noise_mask, noise, seqs).astype(np.int32)
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


class FileTokenDataset(_Base):
    """Sequential batches from a flat int32 token file (np.memmap)."""

    def __init__(self, path: str, batch_size: int, seq_len: int):
        super().__init__(batch_size, seq_len)
        self.path = path
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.tokens_per_batch = batch_size * (seq_len + 1)
        if len(self.tokens) < self.tokens_per_batch:
            raise ValueError(f"{path} too small for one batch")

    def batch_at(self, step: int) -> dict:
        n = len(self.tokens) - self.tokens_per_batch
        off = (step * self.tokens_per_batch) % max(n, 1)
        chunk = np.asarray(self.tokens[off:off + self.tokens_per_batch])
        chunk = chunk.reshape(self.batch_size, self.seq_len + 1)
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}

    @staticmethod
    def write_corpus(path: str, tokens: np.ndarray) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.asarray(tokens, dtype=np.int32).tofile(path)


def make_dataset(kind: str, batch_size: int, seq_len: int, vocab_size: int,
                 path: str | None = None, seed: int = 0) -> _Base:
    if kind == "synthetic":
        return SyntheticLMDataset(batch_size, seq_len, vocab_size, seed)
    if kind == "file":
        assert path
        return FileTokenDataset(path, batch_size, seq_len)
    raise ValueError(kind)
