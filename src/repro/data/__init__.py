from repro.data.pipeline import (  # noqa: F401
    FileTokenDataset,
    PrefetchingLoader,
    SyntheticLMDataset,
    make_dataset,
)
