from repro.data.pipeline import (  # noqa: F401
    FileTokenDataset,
    SyntheticLMDataset,
    make_dataset,
)
