"""Cluster resource model: resources, nodes, containers, requests.

Mirrors the YARN objects the TonY AM negotiates with — memory/vcores/GPUs per
container, node labels (e.g. 'gpu', 'highmem'), and container lifecycle.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from enum import Enum


@dataclass(frozen=True)
class Resource:
    memory_mb: int
    vcores: int
    gpus: int = 0

    def fits_in(self, other: "Resource") -> bool:
        return (self.memory_mb <= other.memory_mb
                and self.vcores <= other.vcores
                and self.gpus <= other.gpus)

    def __add__(self, o: "Resource") -> "Resource":
        return Resource(self.memory_mb + o.memory_mb, self.vcores + o.vcores,
                        self.gpus + o.gpus)

    def __sub__(self, o: "Resource") -> "Resource":
        return Resource(self.memory_mb - o.memory_mb, self.vcores - o.vcores,
                        self.gpus - o.gpus)

    @property
    def nonnegative(self) -> bool:
        return self.memory_mb >= 0 and self.vcores >= 0 and self.gpus >= 0


ZERO = Resource(0, 0, 0)


@dataclass
class Node:
    node_id: str
    capacity: Resource
    labels: frozenset[str] = frozenset()
    used: Resource = ZERO

    def can_fit(self, r: Resource) -> bool:
        return (r + self.used).fits_in(self.capacity)

    @property
    def available(self) -> Resource:
        return self.capacity - self.used


class ContainerState(Enum):
    ALLOCATED = "allocated"
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    RELEASED = "released"
    PREEMPTED = "preempted"


_container_ids = itertools.count(1)


@dataclass
class Container:
    container_id: str
    node_id: str
    resource: Resource
    state: ContainerState = ContainerState.ALLOCATED
    exit_status: int | None = None
    # RM-side reason for a non-clean end state (e.g. which queue preempted
    # this container) — the AM folds it into the task's failure attribution
    diagnostics: str | None = None

    @staticmethod
    def fresh(node_id: str, resource: Resource) -> "Container":
        return Container(f"container_{next(_container_ids):06d}", node_id, resource)


@dataclass(frozen=True)
class ContainerRequest:
    """One container ask: resource + optional node-label constraint + queue."""
    resource: Resource
    node_label: str | None = None
    priority: int = 0


@dataclass
class TaskSpec:
    """Per-task-type specification parsed from the job's XML config."""
    task_type: str                 # worker | ps | chief | evaluator | ...
    instances: int
    resource: Resource
    node_label: str | None = None
    # elastic gang floor (tony.<task>.min-instances): the AM may run this
    # task type with as few as ``min_instances`` members when the cluster
    # cannot fit the full gang (and shed members down to it after INFRA
    # losses mid-attempt). None (default) = rigid: exactly ``instances``
    # members or the attempt fails — elasticity is strictly opt-in.
    min_instances: int | None = None

    @property
    def floor(self) -> int:
        return self.instances if self.min_instances is None else self.min_instances

    @property
    def elastic(self) -> bool:
        return self.floor < self.instances


@dataclass
class JobSpec:
    """Everything the TonY client packages and submits."""
    name: str
    tasks: dict[str, TaskSpec]
    queue: str = "default"
    ml_program: str = ""           # entry-point reference
    venv: str = ""                 # virtualenv / docker image reference
    args: dict[str, str] = field(default_factory=dict)
    scheduler_conf: dict[str, str] = field(default_factory=dict)
    max_app_attempts: int = 3

    def total_resource(self) -> Resource:
        tot = ZERO
        for t in self.tasks.values():
            for _ in range(t.instances):
                tot = tot + t.resource
        return tot


class PortAllocator:
    """Process-wide fake port allocator (one per simulated cluster host)."""

    def __init__(self, start: int = 20000):
        self._next = start
        self._lock = threading.Lock()

    def allocate(self) -> int:
        with self._lock:
            p = self._next
            self._next += 1
            return p
