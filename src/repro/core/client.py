"""TonY Client — the user-facing library.

Packages the job (XML config + ML program reference + venv reference) into an
archive, submits to the pluggable cluster scheduler, launches the AM, and
surfaces status / UI URL / task logs back to the user (paper §2.1).
"""
from __future__ import annotations

import io
import json
import os
import tarfile
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Callable

from repro.core.appmaster import ApplicationMaster, JobResult
from repro.core.config import to_tony_xml
from repro.core.events import EventLog
from repro.core.failures import RetryPolicy, TaskDiagnostics
from repro.core.resources import JobSpec
from repro.core.rm import ResourceManager
from repro.core.speculation import SpeculationPolicy
from repro.core.task_executor import MLProgram


def format_failure_report(result: JobResult) -> str:
    """Render a failed (or flaky) job's diagnostics as the one-stop text the
    user sees: per-task classification, message, and traceback."""
    if result.succeeded and len(result.attempts) == 1:
        return f"{result.app_id}: SUCCEEDED in 1 attempt"
    lines = [f"{result.app_id}: {result.final_status} "
             f"after {len(result.attempts)} attempt(s)"]
    for key, diag in sorted(result.diagnostics.items()):
        lines.append(f"  {diag.describe().replace(diag.task_id, key, 1)}")
        if diag.traceback:
            lines.extend("    | " + ln
                         for ln in diag.traceback.rstrip().splitlines())
    return "\n".join(lines)


class SchedulerBackend:
    """Generic scheduler interface (paper: 'the client interface is generic
    and its implementation can support submitting to multiple schedulers')."""

    def submit(self, job: JobSpec, archive_path: str,
               ml_program: MLProgram) -> "JobHandle":
        raise NotImplementedError


@dataclass
class JobHandle:
    app_id: str
    _thread: threading.Thread
    _result_box: dict
    rm: ResourceManager

    def wait(self, timeout: float | None = None) -> JobResult:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"job {self.app_id} still running")
        return self._result_box["result"]

    @property
    def state(self) -> str:
        return self.rm.app_state(self.app_id)

    def result(self) -> JobResult | None:
        return self._result_box.get("result")

    def diagnostics(self) -> dict[str, TaskDiagnostics]:
        res = self.result()
        return dict(res.diagnostics) if res else {}


class YarnLikeBackend(SchedulerBackend):
    """Submits to the in-process simulated RM (the container-friendly stand-in
    for YARN; swapping this class is the paper's scheduler-pluggability)."""

    def __init__(self, rm: ResourceManager, workdir: str = "",
                 retry_policy: RetryPolicy | None = None,
                 speculation: SpeculationPolicy | None = None):
        self.rm = rm
        self.workdir = workdir
        self.retry_policy = retry_policy
        self.speculation = speculation

    def submit(self, job: JobSpec, archive_path: str,
               ml_program: MLProgram) -> JobHandle:
        app_id = self.rm.submit_application(job.name, job.queue)
        am = ApplicationMaster(self.rm, app_id, job, ml_program,
                               workdir=self.workdir,
                               retry_policy=self.retry_policy,
                               speculation=self.speculation)
        box: dict = {}

        def run():
            box["result"] = am.run()

        t = threading.Thread(target=run, name=f"am-{app_id}", daemon=True)
        t.start()
        return JobHandle(app_id, t, box, self.rm)


class TonYClient:
    def __init__(self, backend: SchedulerBackend, events: EventLog | None = None):
        self.backend = backend
        self.events = events or EventLog()

    # ------------------------------------------------------------------
    def package_archive(self, job: JobSpec, workdir: str | None = None) -> str:
        """Build the submission archive: tony.xml + program + venv manifest
        (a real tarball, as the client ships to the cluster)."""
        workdir = workdir or tempfile.mkdtemp(prefix="tony-archive-")
        os.makedirs(workdir, exist_ok=True)
        path = os.path.join(workdir, f"{job.name}.tar.gz")
        with tarfile.open(path, "w:gz") as tar:
            def add_bytes(name: str, data: bytes):
                info = tarfile.TarInfo(name)
                info.size = len(data)
                tar.addfile(info, io.BytesIO(data))

            add_bytes("tony.xml", to_tony_xml(job).encode())
            add_bytes("program.ref", (job.ml_program or "inline").encode())
            add_bytes("venv.ref", (job.venv or "system").encode())
            add_bytes("args.json", json.dumps(job.args, sort_keys=True).encode())
        return path

    def submit(self, job: JobSpec, ml_program: MLProgram) -> JobHandle:
        t0 = time.monotonic()
        archive = self.package_archive(job)
        handle = self.backend.submit(job, archive, ml_program)
        self.events.emit("client", "job_submitted", app_id=handle.app_id,
                         archive=archive, latency_s=time.monotonic() - t0)
        return handle

    def run_and_wait(self, job: JobSpec, ml_program: MLProgram,
                     timeout: float | None = None) -> JobResult:
        return self.submit(job, ml_program).wait(timeout)


MLProgramT = Callable  # re-export convenience
