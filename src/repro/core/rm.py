"""Simulated ResourceManager: capacity-scheduler queues, labelled nodes,
container allocation/release, and application lifecycle.

This is the pluggable "cluster scheduler" behind the TonY client interface
(the paper's YARN). It is deliberately a faithful *model*, not a mock: queue
capacity shares are enforced, node labels constrain placement, resources are
conserved, and every transition is event-logged so scheduling invariants can
be property-tested.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Collection

from repro.core.chaos import NO_CHAOS, FaultInjector
from repro.core.events import EventLog
from repro.core.failures import FailureClass, TaskDiagnostics
from repro.core.resources import (
    ZERO,
    Container,
    ContainerRequest,
    ContainerState,
    Node,
    Resource,
)


@dataclass
class Queue:
    name: str
    capacity_fraction: float          # share of cluster resources
    used: Resource = ZERO


class AllocationError(RuntimeError):
    pass


#: Blacklist scope used when no queue is given. It deliberately matches the
#: default queue name, so single-queue clusters behave exactly as before
#: scopes existed.
DEFAULT_SCOPE = "default"


class NodeHealthTracker:
    """Blacklist nodes that keep producing INFRA failures, per queue scope.

    A flaky host (bad GPU, broken disk, memory pressure) fails every task
    scheduled onto it; without tracking, the RM re-allocates each retried
    attempt straight back onto the same node and the retry budget burns on
    known-bad hardware. After ``threshold`` classified INFRA failures the
    node is excluded from placement, with timed parole (``parole_s``) so a
    recovered host rejoins — on parole it re-enters one strike from
    re-blacklisting rather than with a clean slate.

    Strikes are charged per *scope* (the RM uses the charging app's queue):
    a node that keeps OOM-killing queue A's heavyweight containers is not
    evicted from queue B's placement — B's smaller tasks may run there
    fine, and one tenant's workload must not poison another's capacity.
    Parole is per-scope for the same reason.

    Only INFRA counts: FATAL_USER is the program's fault and TRANSIENT
    (teardown of innocent siblings, heartbeat blips, contention) would
    poison nodes that merely hosted a collateral victim.
    """

    def __init__(self, threshold: int = 3, parole_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic,
                 events: EventLog | None = None):
        self.threshold = threshold
        self.parole_s = parole_s
        self.clock = clock
        self.events = events
        self._lock = threading.Lock()
        self._failures: dict[tuple[str, str], int] = {}     # (scope, node)
        self._parole_at: dict[tuple[str, str], float] = {}  # -> parole deadline

    def record_failure(self, node_id: str, diag: TaskDiagnostics,
                       scope: str = DEFAULT_SCOPE) -> bool:
        """Count one attributed failure against ``node_id`` under ``scope``.
        Returns True when this failure tipped the node into the blacklist."""
        if diag.classification is not FailureClass.INFRA:
            return False
        with self._lock:
            key = (scope, node_id)
            n = self._failures.get(key, 0) + 1
            self._failures[key] = n
            if n >= self.threshold and key not in self._parole_at:
                self._parole_at[key] = self.clock() + self.parole_s
                if self.events is not None:
                    self.events.emit("rm", "node_blacklisted", node=node_id,
                                     scope=scope,
                                     infra_failures=n, oom=diag.oom,
                                     parole_s=self.parole_s,
                                     reason=diag.describe())
                return True
        return False

    def record_success(self, node_id: str, scope: str = DEFAULT_SCOPE) -> None:
        """A clean attempt on the node wipes its strike count in ``scope``."""
        with self._lock:
            self._failures.pop((scope, node_id), None)

    def is_blacklisted(self, node_id: str, scope: str = DEFAULT_SCOPE) -> bool:
        with self._lock:
            key = (scope, node_id)
            deadline = self._parole_at.get(key)
            if deadline is None:
                return False
            if self.clock() >= deadline:
                # parole: allow the node back, one strike from re-blacklist
                del self._parole_at[key]
                self._failures[key] = self.threshold - 1
                if self.events is not None:
                    self.events.emit("rm", "node_paroled", node=node_id,
                                     scope=scope)
                return False
            return True

    def blacklisted(self, scope: str | None = None) -> list[str]:
        """Node ids currently blacklisted — in ``scope``, or in any scope
        when ``scope`` is None."""
        return sorted({n for (s, n) in list(self._parole_at)
                       if (scope is None or s == scope)
                       and self.is_blacklisted(n, s)})

    def snapshot(self) -> dict:
        # default-scope entries keep bare node-id keys (the common
        # single-queue case); other scopes render as "node@scope"
        def key(scope: str, node: str) -> str:
            return node if scope == DEFAULT_SCOPE else f"{node}@{scope}"
        with self._lock:
            return {"failures": {key(s, n): c
                                 for (s, n), c in self._failures.items()},
                    "blacklisted": sorted(key(s, n)
                                          for (s, n) in self._parole_at)}


_app_ids = itertools.count(1)


class ResourceManager:
    """YARN-RM-alike. Thread-safe; all public methods may be called from AM
    threads."""

    def __init__(self, nodes: list[Node], queues: dict[str, float] | None = None,
                 event_log: EventLog | None = None, elastic: bool = False,
                 chaos: FaultInjector | None = None,
                 health: NodeHealthTracker | None = None):
        self.nodes = {n.node_id: n for n in nodes}
        queues = queues or {"default": 1.0}
        assert abs(sum(queues.values()) - 1.0) < 1e-6, "queue shares must sum to 1"
        self.queues = {n: Queue(n, f) for n, f in queues.items()}
        # elastic (YARN-style): queues may borrow idle capacity beyond their
        # share; preemption (try_preempt_for) reclaims it on demand
        self.elastic = elastic
        self.events = event_log or EventLog()
        # chaos: fault-injection hooks (no-op by default); health: node
        # blacklisting after repeated INFRA failures (core/chaos.py docs)
        self.chaos = chaos or NO_CHAOS
        self.health = health or NodeHealthTracker(events=self.events)
        self._lock = threading.RLock()
        self._containers: dict[str, Container] = {}
        self._container_queue: dict[str, str] = {}
        self._apps: dict[str, dict] = {}

    # ------------------------------------------------------------------
    def cluster_capacity(self) -> Resource:
        tot = ZERO
        for n in self.nodes.values():
            tot = tot + n.capacity
        return tot

    def queue_limit(self, queue: str) -> Resource:
        cap = self.cluster_capacity()
        f = self.queues[queue].capacity_fraction
        return Resource(int(cap.memory_mb * f), int(cap.vcores * f),
                        int(cap.gpus * f))

    # ------------------------------------------------------------------
    def submit_application(self, name: str, queue: str) -> str:
        with self._lock:
            if queue not in self.queues:
                raise AllocationError(f"unknown queue {queue!r}")
            app_id = f"application_{next(_app_ids):06d}"
            self._apps[app_id] = {"name": name, "queue": queue, "state": "SUBMITTED"}
            self.events.emit("rm", "app_submitted", app_id=app_id, queue=queue)
            return app_id

    def app_state(self, app_id: str) -> str:
        return self._apps[app_id]["state"]

    def set_app_state(self, app_id: str, state: str) -> None:
        with self._lock:
            self._apps[app_id]["state"] = state
            self.events.emit("rm", "app_state", app_id=app_id, state=state)

    # ------------------------------------------------------------------
    def allocate(self, app_id: str, request: ContainerRequest,
                 exclude_nodes: Collection[str] = ()) -> Container:
        """Allocate one container honoring queue share + node labels.

        Raises AllocationError when the queue is over its share, no labelled
        node can fit the request, or a chaos plan injects a failure.
        Blacklisted nodes (NodeHealthTracker) are excluded from placement;
        ``exclude_nodes`` additionally rules out specific hosts — the AM
        uses it to keep a speculative backup off its straggler's node.
        """
        chaos_error = self.chaos.on_allocate(app_id)
        if chaos_error is not None:
            self.events.emit("rm", "allocation_chaos_failed", app_id=app_id,
                             error=chaos_error)
            raise AllocationError(chaos_error)
        with self._lock:
            queue = self._apps[app_id]["queue"]
            q = self.queues[queue]
            limit = self.queue_limit(queue)
            if not self.elastic and not (q.used + request.resource).fits_in(limit):
                raise AllocationError(
                    f"queue {queue!r} over capacity: used={q.used} ask={request.resource} limit={limit}")
            for node in sorted(self.nodes.values(),
                               key=lambda n: -n.available.memory_mb):
                if node.node_id in exclude_nodes:
                    continue
                if request.node_label and request.node_label not in node.labels:
                    continue
                if self.health.is_blacklisted(node.node_id, queue):
                    continue
                if node.can_fit(request.resource):
                    node.used = node.used + request.resource
                    q.used = q.used + request.resource
                    c = Container.fresh(node.node_id, request.resource)
                    self._containers[c.container_id] = c
                    self._container_queue[c.container_id] = queue
                    self.events.emit("rm", "container_allocated",
                                     app_id=app_id, container_id=c.container_id,
                                     node=node.node_id,
                                     label=request.node_label,
                                     memory_mb=request.resource.memory_mb,
                                     gpus=request.resource.gpus)
                    return c
            raise AllocationError(
                f"no node satisfies {request.resource} label={request.node_label!r}"
                + (f" excluding {sorted(exclude_nodes)}" if exclude_nodes else ""))

    def allocate_many(self, app_id: str, request: ContainerRequest,
                      count: int) -> list[Container]:
        out = []
        try:
            for _ in range(count):
                out.append(self.allocate(app_id, request))
        except AllocationError:
            for c in out:
                self.release(c.container_id)
            raise
        return out

    def allocate_up_to(self, app_id: str, request: ContainerRequest,
                       count: int, minimum: int = 0) -> list[Container]:
        """Best-effort gang ask: allocate up to ``count`` containers,
        accepting a partial grant as long as at least ``minimum`` landed.

        This is the elastic half of gang negotiation: the AM asks for the
        full task-type width but tolerates a shortfall down to the task's
        ``min_instances`` floor. Below the floor every partial container is
        released (no leaks) and the AllocationError propagates, exactly like
        ``allocate_many``.
        """
        out: list[Container] = []
        try:
            for _ in range(count):
                out.append(self.allocate(app_id, request))
        except AllocationError:
            if len(out) < minimum:
                for c in out:
                    self.release(c.container_id)
                raise
            self.events.emit("rm", "partial_allocation", app_id=app_id,
                             granted=len(out), requested=count,
                             minimum=minimum)
        return out

    def release(self, container_id: str,
                state: ContainerState = ContainerState.RELEASED,
                exit_status: int | None = None,
                diagnostics: str | None = None) -> None:
        with self._lock:
            c = self._containers.get(container_id)
            if c is None or c.state in (ContainerState.RELEASED,
                                        ContainerState.COMPLETED,
                                        ContainerState.FAILED,
                                        ContainerState.PREEMPTED):
                return
            node = self.nodes[c.node_id]
            node.used = node.used - c.resource
            queue = self._container_queue[container_id]
            self.queues[queue].used = self.queues[queue].used - c.resource
            c.state = state
            c.exit_status = exit_status
            if diagnostics is not None:
                c.diagnostics = diagnostics
            self.events.emit("rm", "container_released",
                             container_id=container_id, state=state.value)

    def mark_running(self, container_id: str) -> None:
        with self._lock:
            self._containers[container_id].state = ContainerState.RUNNING

    # ------------------------------------------------------------------
    # Capacity-scheduler preemption: queues running OVER their share can be
    # reclaimed when an under-share queue cannot satisfy a request.

    def queue_over_share(self, queue: str) -> bool:
        with self._lock:
            q = self.queues[queue]
            lim = self.queue_limit(queue)
            return not q.used.fits_in(lim)

    def _gang_fits(self, request: ContainerRequest, count: int,
                   queue: str = DEFAULT_SCOPE) -> bool:
        """Greedy bin check: could ``count`` copies of ``request`` be placed
        on the currently-available node capacities, from ``queue``'s view of
        the blacklist?"""
        avail = []
        for n in self.nodes.values():
            if request.node_label and request.node_label not in n.labels:
                continue
            if self.health.is_blacklisted(n.node_id, queue):
                continue
            avail.append(n.available)
        placed = 0
        for free in sorted(avail, key=lambda r: -r.memory_mb):
            while request.resource.fits_in(free) and placed < count:
                free = free - request.resource
                placed += 1
        return placed >= count

    def try_preempt_for(self, app_id: str, request: ContainerRequest,
                        count: int = 1) -> int:
        """Preempt containers from over-share queues until ``count`` copies of
        ``request`` could fit (or no victims remain). Returns the number
        preempted. The victim AMs observe their containers' PREEMPTED state
        via executor heartbeats and relaunch through their normal
        fault-tolerance path."""
        preempted = 0
        with self._lock:
            my_queue = self._apps[app_id]["queue"]
            victims = [c for c in self.live_containers()
                       if self._container_queue[c.container_id] != my_queue
                       and self.queue_over_share(
                           self._container_queue[c.container_id])]
            for victim in victims:
                if self._gang_fits(request, count, my_queue):
                    break
                self.release(victim.container_id, ContainerState.PREEMPTED,
                             exit_status=137,
                             diagnostics=f"preempted to satisfy queue "
                                         f"{my_queue!r} (capacity scheduler)")
                victim.state = ContainerState.PREEMPTED
                self.events.emit("rm", "container_preempted",
                                 container_id=victim.container_id,
                                 victim_queue=self._container_queue[
                                     victim.container_id],
                                 for_queue=my_queue)
                preempted += 1
        return preempted

    def container_state(self, container_id: str) -> ContainerState:
        with self._lock:
            return self._containers[container_id].state

    # ------------------------------------------------------------------
    # Node health: the AM attributes task failures to the hosting node so
    # repeated INFRA trouble gets the node excluded from future placement.

    def report_node_failure(self, node_id: str, diag: TaskDiagnostics,
                            queue: str = DEFAULT_SCOPE) -> bool:
        if node_id not in self.nodes:
            return False
        return self.health.record_failure(node_id, diag, scope=queue)

    def report_node_success(self, node_id: str,
                            queue: str = DEFAULT_SCOPE) -> None:
        if node_id in self.nodes:
            self.health.record_success(node_id, scope=queue)

    # ------------------------------------------------------------------
    def live_containers(self) -> list[Container]:
        with self._lock:
            return [c for c in self._containers.values()
                    if c.state in (ContainerState.ALLOCATED, ContainerState.RUNNING)]

    def invariants_ok(self) -> bool:
        """Resource conservation: per-node and per-queue accounting matches
        the sum of live containers; nothing exceeds capacity."""
        with self._lock:
            per_node: dict[str, Resource] = {nid: ZERO for nid in self.nodes}
            per_queue: dict[str, Resource] = {qn: ZERO for qn in self.queues}
            for c in self.live_containers():
                per_node[c.node_id] = per_node[c.node_id] + c.resource
                per_queue[self._container_queue[c.container_id]] = (
                    per_queue[self._container_queue[c.container_id]] + c.resource)
            for nid, n in self.nodes.items():
                if per_node[nid] != n.used or not n.used.fits_in(n.capacity):
                    return False
                if not n.used.nonnegative:
                    return False
            for qn, q in self.queues.items():
                if per_queue[qn] != q.used:
                    return False
            return True


def make_cluster(num_gpu_nodes: int = 4, num_cpu_nodes: int = 4,
                 gpus_per_node: int = 4, memory_mb: int = 256_000,
                 vcores: int = 64,
                 queues: dict[str, float] | None = None,
                 event_log: EventLog | None = None,
                 chaos: FaultInjector | None = None,
                 health: NodeHealthTracker | None = None) -> ResourceManager:
    """Convenience factory for a small heterogeneous cluster."""
    nodes = []
    for i in range(num_gpu_nodes):
        nodes.append(Node(f"gpu-node-{i}", Resource(memory_mb, vcores, gpus_per_node),
                          frozenset({"gpu"})))
    for i in range(num_cpu_nodes):
        nodes.append(Node(f"cpu-node-{i}", Resource(memory_mb, vcores, 0),
                          frozenset({"highmem"})))
    return ResourceManager(nodes, queues, event_log, chaos=chaos, health=health)
