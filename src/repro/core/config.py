"""TonY job configuration: XML schema (tony.xml) -> JobSpec.

Faithful to TonY's property style::

    <configuration>
      <property><name>tony.worker.instances</name><value>4</value></property>
      <property><name>tony.worker.memory</name><value>8192</value></property>
      <property><name>tony.worker.gpus</name><value>1</value></property>
      <property><name>tony.worker.node-label</name><value>gpu</value></property>
      <property><name>tony.ps.instances</name><value>2</value></property>
      <property><name>tony.yarn.queue</name><value>default</value></property>
      <property><name>tony.application.name</name><value>mnist</value></property>
    </configuration>
"""
from __future__ import annotations

import io
import xml.etree.ElementTree as ET

from repro.core.resources import JobSpec, Resource, TaskSpec

_DEFAULT_RESOURCE = Resource(memory_mb=2048, vcores=1, gpus=0)
_RESERVED = {"application", "yarn", "am"}


def parse_tony_xml(text_or_path: str) -> JobSpec:
    if "\n" in text_or_path or text_or_path.strip().startswith("<"):
        tree = ET.parse(io.StringIO(text_or_path))
    else:
        tree = ET.parse(text_or_path)
    props: dict[str, str] = {}
    for prop in tree.getroot().findall("property"):
        name = prop.findtext("name", "").strip()
        value = prop.findtext("value", "").strip()
        if name:
            props[name] = value
    return job_spec_from_props(props)


def job_spec_from_props(props: dict[str, str]) -> JobSpec:
    task_fields: dict[str, dict[str, str]] = {}
    name = props.get("tony.application.name", "tony-job")
    queue = props.get("tony.yarn.queue", "default")
    ml_program = props.get("tony.application.program", "")
    venv = props.get("tony.application.venv", "")
    max_attempts = int(props.get("tony.application.max-attempts", "3"))
    args = {k.split("tony.args.", 1)[1]: v for k, v in props.items()
            if k.startswith("tony.args.")}
    sched = {k.split("tony.yarn.", 1)[1]: v for k, v in props.items()
             if k.startswith("tony.yarn.")}

    for key, value in props.items():
        parts = key.split(".")
        if len(parts) != 3 or parts[0] != "tony":
            continue
        _, task_type, field = parts
        if task_type in _RESERVED or task_type in ("args", "yarn"):
            continue
        task_fields.setdefault(task_type, {})[field] = value

    tasks: dict[str, TaskSpec] = {}
    for task_type, fields in task_fields.items():
        instances = int(fields.get("instances", "0"))
        if instances <= 0:
            continue
        res = Resource(
            memory_mb=int(fields.get("memory", _DEFAULT_RESOURCE.memory_mb)),
            vcores=int(fields.get("vcores", _DEFAULT_RESOURCE.vcores)),
            gpus=int(fields.get("gpus", "0")),
        )
        # elastic gang floor: tony.<task>.min-instances lets the AM run the
        # task type degraded, down to this many members, instead of failing
        # when the cluster can't fit the full gang
        min_instances: int | None = None
        if "min-instances" in fields:
            min_instances = int(fields["min-instances"])
            if not 1 <= min_instances <= instances:
                raise ValueError(
                    f"tony.{task_type}.min-instances={min_instances} must be "
                    f"in [1, instances={instances}]")
        tasks[task_type] = TaskSpec(task_type, instances, res,
                                    fields.get("node-label") or None,
                                    min_instances=min_instances)
    if not tasks:
        raise ValueError("job config declares no task instances")
    return JobSpec(name=name, tasks=tasks, queue=queue, ml_program=ml_program,
                   venv=venv, args=args, scheduler_conf=sched,
                   max_app_attempts=max_attempts)


def to_tony_xml(spec: JobSpec) -> str:
    """Serialize a JobSpec back to tony.xml (round-trip tested)."""
    root = ET.Element("configuration")

    def add(name, value):
        p = ET.SubElement(root, "property")
        ET.SubElement(p, "name").text = name
        ET.SubElement(p, "value").text = str(value)

    add("tony.application.name", spec.name)
    add("tony.yarn.queue", spec.queue)
    if spec.ml_program:
        add("tony.application.program", spec.ml_program)
    if spec.venv:
        add("tony.application.venv", spec.venv)
    add("tony.application.max-attempts", spec.max_app_attempts)
    for t in spec.tasks.values():
        add(f"tony.{t.task_type}.instances", t.instances)
        add(f"tony.{t.task_type}.memory", t.resource.memory_mb)
        add(f"tony.{t.task_type}.vcores", t.resource.vcores)
        add(f"tony.{t.task_type}.gpus", t.resource.gpus)
        if t.node_label:
            add(f"tony.{t.task_type}.node-label", t.node_label)
        if t.min_instances is not None:
            add(f"tony.{t.task_type}.min-instances", t.min_instances)
    for k, v in spec.args.items():
        add(f"tony.args.{k}", v)
    return ET.tostring(root, encoding="unicode")
