"""Job history + metrics analysis (the paper's monitoring story + the Dr.
Elephant hook from §3: aggregate per-task metrics, suggest better settings).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.appmaster import JobResult
from repro.core.resources import JobSpec


@dataclass
class HistoryEntry:
    job: JobSpec
    result: JobResult


class JobHistoryServer:
    """One place to find UI URL, task logs and attempts per application
    (paper: 'users can directly access the visualization UI and task logs
    from one place')."""

    def __init__(self):
        self._entries: dict[str, HistoryEntry] = {}

    def record(self, job: JobSpec, result: JobResult) -> None:
        self._entries[result.app_id] = HistoryEntry(job, result)

    def get(self, app_id: str) -> HistoryEntry:
        return self._entries[app_id]

    def all_apps(self) -> list[str]:
        return sorted(self._entries)

    def summary(self, app_id: str) -> dict:
        e = self._entries[app_id]
        return {
            "app_id": app_id,
            "name": e.job.name,
            "status": e.result.final_status,
            "attempts": len(e.result.attempts),
            "ui_url": e.result.ui_url,
            "task_logs": sorted(e.result.task_logs),
        }


@dataclass
class Suggestion:
    task_type: str
    kind: str
    message: str


class MetricsAnalyzer:
    """Dr.-Elephant-style advisor: compares requested resources against
    observed task metrics and suggests config changes."""

    MEM_WASTE_THRESHOLD = 0.5   # using <50% of requested memory
    SLOW_HEARTBEAT_RATIO = 2.0

    def analyze(self, job: JobSpec, result: JobResult) -> list[Suggestion]:
        out: list[Suggestion] = []
        peak_by_type: dict[str, float] = {}
        for task_key, m in result.metrics.items():
            ttype = task_key.split("/")[-1].split(":")[0]
            if "peak_memory_mb" in m:
                peak_by_type[ttype] = max(peak_by_type.get(ttype, 0.0),
                                          m["peak_memory_mb"])
        for ttype, tspec in job.tasks.items():
            peak = peak_by_type.get(ttype)
            if peak is not None and peak < tspec.resource.memory_mb * self.MEM_WASTE_THRESHOLD:
                out.append(Suggestion(
                    ttype, "memory_overprovisioned",
                    f"{ttype} requested {tspec.resource.memory_mb}MB but peaked at "
                    f"{peak:.0f}MB; consider lowering tony.{ttype}.memory"))
        if len(result.attempts) > 1:
            out.append(Suggestion(
                "*", "flaky",
                f"job needed {len(result.attempts)} attempts; check task logs "
                f"for transient failures"))
        return out


@dataclass
class UtilizationReport:
    per_task_type: dict[str, dict] = field(default_factory=dict)
