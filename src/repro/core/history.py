"""Job history + metrics analysis (the paper's monitoring story + the Dr.
Elephant hook from §3: aggregate per-task metrics, suggest better settings).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.appmaster import JobResult
from repro.core.failures import FailureClass
from repro.core.resources import JobSpec


@dataclass
class HistoryEntry:
    job: JobSpec
    result: JobResult


class JobHistoryServer:
    """One place to find UI URL, task logs and attempts per application
    (paper: 'users can directly access the visualization UI and task logs
    from one place')."""

    def __init__(self):
        self._entries: dict[str, HistoryEntry] = {}

    def record(self, job: JobSpec, result: JobResult) -> None:
        self._entries[result.app_id] = HistoryEntry(job, result)

    def get(self, app_id: str) -> HistoryEntry:
        return self._entries[app_id]

    def all_apps(self) -> list[str]:
        return sorted(self._entries)

    def summary(self, app_id: str) -> dict:
        """One-stop answer to "what happened to my job" — status, attempts,
        logs, and (for failures) per-task attribution + retry advice."""
        e = self._entries[app_id]
        diags = e.result.diagnostics
        return {
            "app_id": app_id,
            "name": e.job.name,
            "status": e.result.final_status,
            "attempts": len(e.result.attempts),
            "ui_url": e.result.ui_url,
            "task_logs": sorted(e.result.task_logs),
            "diagnostics": {k: d.to_dict() for k, d in sorted(diags.items())},
            "failure_reasons": e.result.failure_summary(),
            "retry_advice": self._retry_advice(e.result),
            # checkpoint-aware recovery + node health, per the chaos subsystem
            "resumed_attempts": dict(e.result.resumed_attempts),
            "blacklisted_nodes": list(e.result.blacklisted_nodes),
            # speculative execution: who lagged, and how each backup race
            # ended ("a<attempt>/<task>" -> won | cancelled | failed)
            "stragglers": sorted({t for r in e.result.attempts
                                  for t in r.stragglers}),
            "speculation": dict(e.result.speculation),
            # elastic gang resize: attempt -> final per-task-type membership
            # for every attempt that ran below the configured gang
            "resized_attempts": {a: dict(c) for a, c
                                 in e.result.resized_attempts.items()},
        }

    @staticmethod
    def _retry_advice(result: JobResult) -> str:
        if result.succeeded:
            return ("recovered after retries; see diagnostics for the "
                    "transient causes" if len(result.attempts) > 1 else "")
        classes = {d.classification for d in result.diagnostics.values()}
        if FailureClass.FATAL_USER in classes:
            return ("fix the program: a FATAL_USER failure (bad import/"
                    "attribute/name) can never succeed on retry — the AM "
                    "failed fast instead of burning attempts")
        if classes == {FailureClass.INFRA}:
            return ("cluster-side failure (preemption/container/executor); "
                    "resubmit or pick a less contended queue")
        return ("transient failures exhausted the attempt budget; raise "
                "tony.application.max-attempts or investigate the flakiness "
                "in the task logs")


@dataclass
class Suggestion:
    task_type: str
    kind: str
    message: str


class MetricsAnalyzer:
    """Dr.-Elephant-style advisor: compares requested resources against
    observed task metrics and suggests config changes."""

    MEM_WASTE_THRESHOLD = 0.5   # using <50% of requested memory
    SLOW_HEARTBEAT_RATIO = 2.0

    def analyze(self, job: JobSpec, result: JobResult) -> list[Suggestion]:
        out: list[Suggestion] = []
        peak_by_type: dict[str, float] = {}
        for task_key, m in result.metrics.items():
            ttype = task_key.split("/")[-1].split(":")[0]
            if "peak_memory_mb" in m:
                peak_by_type[ttype] = max(peak_by_type.get(ttype, 0.0),
                                          m["peak_memory_mb"])
        for ttype, tspec in job.tasks.items():
            peak = peak_by_type.get(ttype)
            if peak is not None and peak < tspec.resource.memory_mb * self.MEM_WASTE_THRESHOLD:
                out.append(Suggestion(
                    ttype, "memory_overprovisioned",
                    f"{ttype} requested {tspec.resource.memory_mb}MB but peaked at "
                    f"{peak:.0f}MB; consider lowering tony.{ttype}.memory"))
        if len(result.attempts) > 1:
            out.append(Suggestion(
                "*", "flaky",
                f"job needed {len(result.attempts)} attempts; check task logs "
                f"for transient failures"))
        out.extend(self._straggler_suggestions(result))
        out.extend(self._elastic_suggestions(result))
        out.extend(self._failure_suggestions(result))
        return out

    @staticmethod
    def _elastic_suggestions(result: JobResult) -> list[Suggestion]:
        """Elastic-resize advice: degraded attempts mean the cluster could
        not (or stopped being able to) host the configured gang."""
        resized = result.resized_attempts
        if not resized:
            return []
        detail = "; ".join(
            f"attempt {a}: " + ", ".join(f"{t}={n}" for t, n in sorted(c.items()))
            for a, c in sorted(resized.items()))
        return [Suggestion(
            "*", "elastic_degraded",
            f"{len(resized)} attempt(s) ran below the configured gang "
            f"({detail}); the job survived thanks to min-instances, but "
            "check node health / queue contention — or lower "
            "tony.<task>.instances if degraded throughput is the norm")]

    @staticmethod
    def _straggler_suggestions(result: JobResult) -> list[Suggestion]:
        """Speculation advice: a won race means the original's host was
        slow — point the operator at that node's health."""
        out: list[Suggestion] = []
        won = sorted(k for k, o in result.speculation.items() if o == "won")
        if won:
            nodes = sorted({
                r.nodes.get(k.split("/", 1)[1], "?")
                for r in result.attempts
                for k in won if k.startswith(f"a{r.attempt}/")})
            out.append(Suggestion(
                "*", "straggler",
                "speculative backups beat the originals for " + ", ".join(won)
                + f"; the hosting node(s) {', '.join(nodes)} ran slow — "
                  "check their health (thermal/IO/noisy neighbors) before "
                  "the blacklist has to learn it the hard way"))
        stragglers = sorted({t for r in result.attempts for t in r.stragglers})
        if stragglers and not won:
            out.append(Suggestion(
                "*", "straggler",
                "stragglers detected (" + ", ".join(stragglers)
                + ") but no backup outran them; if this recurs, lower "
                  "tony.speculation.slowdown-factor or patience so backups "
                  "launch earlier"))
        return out

    @staticmethod
    def _failure_suggestions(result: JobResult) -> list[Suggestion]:
        """Per-classification retry advice from the diagnostics subsystem."""
        out: list[Suggestion] = []
        by_class: dict[FailureClass, list[str]] = {}
        oom_tasks: list[str] = []
        for key, d in sorted(result.diagnostics.items()):
            by_class.setdefault(d.classification, []).append(
                f"{key}: {d.exception_type or 'exit'} {d.message}".strip())
            if d.oom:
                oom_tasks.append(key)
        if oom_tasks:
            out.append(Suggestion(
                "*", "oom",
                "tasks died of memory exhaustion (" + ", ".join(oom_tasks)
                + "); raise tony.<task>.memory or shrink the per-container "
                  "batch — repeated OOMs on one host also trip node "
                  "blacklisting"))
        if FailureClass.FATAL_USER in by_class:
            out.append(Suggestion(
                "*", "user_error",
                "FATAL_USER failure — retries were skipped because the "
                "program itself is broken: "
                + "; ".join(by_class[FailureClass.FATAL_USER])))
        if FailureClass.INFRA in by_class:
            out.append(Suggestion(
                "*", "infra",
                "INFRA failures (preemption/container/executor): "
                + "; ".join(by_class[FailureClass.INFRA])))
        if FailureClass.TRANSIENT in by_class and not result.succeeded:
            out.append(Suggestion(
                "*", "transient_exhausted",
                "TRANSIENT failures exhausted the attempt budget; consider "
                "raising tony.application.max-attempts: "
                + "; ".join(by_class[FailureClass.TRANSIENT])))
        return out


@dataclass
class UtilizationReport:
    per_task_type: dict[str, dict] = field(default_factory=dict)
