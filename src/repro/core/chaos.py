"""Deterministic chaos-injection harness (paper §2.2 fault tolerance, made
testable).

TonY's fault-tolerance story — heartbeats, classified failures, retries,
checkpoint restore, node blacklisting — is only trustworthy if faults can be
produced *on demand and reproducibly*. This module provides that substrate:

* ``FaultSpec`` / ``FaultPlan`` — a declarative, seeded plan of faults:
  kill a task at step N, simulate an OOM, drop heartbeats for a window,
  fail an allocation call, or preempt a container mid-attempt.
* ``FaultInjector`` — the runtime that RM / AM / TaskExecutor / the training
  loop consult at their natural hook points. The default (``NO_CHAOS``, an
  injector over an empty plan) makes every hook a cheap no-op so production
  paths pay nothing.

Determinism: faults fire on explicit conditions (task pattern, attempt,
step, elapsed time), never on ambient randomness. The seed is only used by
``FaultPlan.random_plan`` to *generate* a plan — two generations with the
same seed yield the same plan, so chaos CI runs are reproducible.

Every fired fault emits a ``chaos_injected`` event so post-mortems can
distinguish injected trouble from organic trouble.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable

from repro.core.events import EventLog


class FaultKind(Enum):
    KILL_TASK = "kill_task"             # raise in the child at step N
    OOM = "oom"                         # raise an XLA-style RESOURCE_EXHAUSTED
    DROP_HEARTBEATS = "drop_heartbeats"  # suppress heartbeats for a window
    FAIL_ALLOCATION = "fail_allocation"  # RM.allocate raises
    PREEMPT = "preempt"                 # container reclaimed mid-attempt
    SLOW_STEP = "slow_step"             # delay each step in a range (straggler)
    PARTITION = "partition"             # pair-wise network partition window

    def __str__(self) -> str:
        return self.value


class ChaosKill(RuntimeError):
    """Injected task death — classified TRANSIENT like any organic crash."""


class ChaosOOM(RuntimeError):
    """Injected OOM. The message mimics XLA's RESOURCE_EXHAUSTED so the
    failure-classification path (core/failures.py) detects it the same way
    it would a real allocator failure."""


class ChaosPartition(RuntimeError):
    """Injected network partition observed from inside a collective: the
    task's peers became unreachable mid-step. Classified TRANSIENT (a
    partition is the network's fault, not the node's — it must never put a
    host on the blacklist)."""


#: The message format XLA emits when a device allocation fails; the chaos
#: OOM uses it verbatim so detection is exercised end to end.
OOM_MESSAGE = ("RESOURCE_EXHAUSTED: Out of memory while trying to allocate "
               "{nbytes} bytes (chaos-injected)")


@dataclass(frozen=True)
class FaultSpec:
    """One planned fault.

    ``task`` is a task-id pattern: exact (``worker:0``), type-wide
    (``worker:*``) or any (``*``). ``attempt`` gates on the app attempt
    (0 = any attempt). Step-gated kinds (KILL_TASK, OOM) fire when the
    training loop reaches ``at_step``; time-gated kinds (DROP_HEARTBEATS,
    PREEMPT) fire ``after_s`` seconds into the task, DROP_HEARTBEATS for
    ``duration_s``. FAIL_ALLOCATION fires on allocate calls after skipping
    the first ``after_allocs``. ``count`` bounds total firings.

    A KILL_TASK spec with ``in_ckpt_write=True`` fires from the *checkpoint
    writer window* instead of the training loop: the async checkpointer
    consults ``check_ckpt_write`` between staging the arrays and writing the
    COMMIT marker, so ``at_step`` names the checkpoint step being written
    and the kill lands mid-background-write — the resume contract must then
    fall back to the previous committed step.

    SLOW_STEP makes a task a *straggler* rather than a corpse: every step in
    ``[at_step, until_step]`` (``until_step=None`` = to the end) is delayed
    by ``delay_s`` seconds. The delay applies to the whole window; ``count``
    only bounds how many ``chaos_injected`` events the spec emits (one per
    (task, attempt) entering the window). Note on speculative copies: they
    run under a ``#<copy>``-suffixed id (``worker:1#1``), so an exact task
    pattern slows only the original while a type-wide ``worker:*`` pattern
    slows backups too — target ``worker:1#1`` explicitly to slow a backup.

    PARTITION cuts the network between the ``src`` and ``dst`` task-id
    patterns (``task`` is ignored): while the window is open, both endpoints
    stop heartbeating and block in rendezvous. Time-gated specs
    (``after_s``/``duration_s`` from task start) model a transient fabric
    outage the gang can ride out; step-gated specs (``at_step`` set,
    optionally ``until_step``) instead raise ``ChaosPartition`` from the
    ``src`` endpoint's training loop — a collective that noticed its peer
    vanished — which is deterministic per step and classified TRANSIENT.
    """
    kind: FaultKind
    task: str = "worker:0"
    attempt: int = 0
    at_step: int | None = None
    after_s: float = 0.0
    duration_s: float = 0.0
    after_allocs: int = 0
    count: int = 1
    until_step: int | None = None
    delay_s: float = 0.0
    src: str = ""                      # PARTITION endpoint patterns
    dst: str = ""
    in_ckpt_write: bool = False        # KILL_TASK inside the ckpt writer window

    @staticmethod
    def _match(pattern: str, task_id: str) -> bool:
        if pattern == "*":
            return True
        if pattern.endswith(":*"):
            return task_id.split(":")[0] == pattern[:-2]
        return task_id == pattern

    def matches_task(self, task_id: str) -> bool:
        return self._match(self.task, task_id)

    def matches_src(self, task_id: str) -> bool:
        return self._match(self.src or self.task, task_id)

    def matches_endpoint(self, task_id: str) -> bool:
        """True when ``task_id`` is on either side of the partition."""
        return self.matches_src(task_id) or (
            bool(self.dst) and self._match(self.dst, task_id))

    def matches_attempt(self, attempt: int) -> bool:
        return self.attempt == 0 or self.attempt == attempt


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable list of faults. The seed identifies the plan in
    events/logs and drives ``random_plan`` generation."""
    seed: int = 0
    faults: tuple[FaultSpec, ...] = ()

    def add(self, spec: FaultSpec) -> "FaultPlan":
        return FaultPlan(self.seed, self.faults + (spec,))

    @staticmethod
    def random_plan(seed: int, *, steps: int,
                    tasks: tuple[str, ...] = ("worker:0",),
                    n_faults: int = 2,
                    kinds: tuple[FaultKind, ...] = (FaultKind.KILL_TASK,
                                                    FaultKind.OOM)) -> "FaultPlan":
        """Generate a reproducible plan: same seed -> same faults."""
        rng = random.Random(seed)
        faults = tuple(
            FaultSpec(kind=rng.choice(kinds), task=rng.choice(tasks),
                      attempt=0, at_step=rng.randrange(1, max(2, steps)))
            for _ in range(n_faults))
        return FaultPlan(seed=seed, faults=faults)


class FaultInjector:
    """Runtime consulted at the orchestrator's chaos hook points.

    Thread-safe: executors, the AM monitor and RM allocate calls probe it
    concurrently. All hooks short-circuit when the plan is empty.
    """

    def __init__(self, plan: FaultPlan | None = None,
                 events: EventLog | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.plan = plan or FaultPlan()
        self.events = events
        self.clock = clock
        self.sleep = sleep                        # injectable for tests
        self._lock = threading.Lock()
        self._fired: dict[int, int] = {}          # spec index -> firings
        self._task_start: dict[tuple[str, int], float] = {}
        self._hb_dropping: set[tuple[int, str, int]] = set()
        self._slowing: set[tuple[int, str, int]] = set()
        self._partitioning: set[tuple[int, str, int]] = set()
        self._alloc_calls = 0

    @property
    def enabled(self) -> bool:
        return bool(self.plan.faults)

    # ------------------------------------------------------------------
    def _eligible(self, idx: int, spec: FaultSpec) -> bool:
        return self._fired.get(idx, 0) < spec.count

    def _fire(self, idx: int, spec: FaultSpec, **info) -> None:
        self._fired[idx] = self._fired.get(idx, 0) + 1
        if self.events is not None:
            self.events.emit("chaos", "chaos_injected", fault=spec.kind.value,
                             seed=self.plan.seed, spec_index=idx, **info)

    def _specs(self, kind: FaultKind):
        for idx, spec in enumerate(self.plan.faults):
            if spec.kind is kind:
                yield idx, spec

    # ------------------------------------------------------------------
    # Hook: RM.allocate (every container ask)

    def on_allocate(self, app_id: str) -> str | None:
        """Returns an error message when this allocate call should fail
        (the RM raises AllocationError with it), else None."""
        if not self.enabled:
            return None
        with self._lock:
            self._alloc_calls += 1
            for idx, spec in self._specs(FaultKind.FAIL_ALLOCATION):
                if self._eligible(idx, spec) and \
                        self._alloc_calls > spec.after_allocs:
                    self._fire(idx, spec, app_id=app_id,
                               alloc_call=self._alloc_calls)
                    return (f"chaos: injected allocation failure "
                            f"(seed={self.plan.seed}, call #{self._alloc_calls})")
        return None

    # ------------------------------------------------------------------
    # Hook: TaskExecutor start + heartbeat loop

    def task_started(self, task_id: str, attempt: int) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._task_start.setdefault((task_id, attempt), self.clock())

    def drop_heartbeat(self, task_id: str, attempt: int) -> bool:
        """True while this task's heartbeats should be suppressed (a
        simulated network partition / hung node)."""
        if not self.enabled:
            return False
        with self._lock:
            t0 = self._task_start.get((task_id, attempt))
            if t0 is None:
                return False
            elapsed = self.clock() - t0
            for idx, spec in self._specs(FaultKind.DROP_HEARTBEATS):
                if not (spec.matches_task(task_id)
                        and spec.matches_attempt(attempt)):
                    continue
                key = (idx, task_id, attempt)
                in_window = spec.after_s <= elapsed < spec.after_s + spec.duration_s
                if in_window and key not in self._hb_dropping:
                    if not self._eligible(idx, spec):
                        continue
                    self._hb_dropping.add(key)
                    self._fire(idx, spec, task=task_id, attempt=attempt,
                               duration_s=spec.duration_s)
                if in_window and key in self._hb_dropping:
                    return True
        return False

    def partition_active(self, task_id: str | None, attempt: int) -> bool:
        """True while ``task_id`` sits on either side of an open time-gated
        PARTITION window: its heartbeats are dropped and its rendezvous
        blocks (JobContext.rendezvous polls this). Windows run on task-start
        time (``after_s``..``after_s + duration_s``); a task probed before
        ``task_started`` registered it counts as elapsed 0.0. Step-gated
        partition specs (``at_step`` set) are handled by ``check_step``."""
        if not self.enabled or task_id is None:
            return False
        with self._lock:
            t0 = self._task_start.get((task_id, attempt))
            elapsed = 0.0 if t0 is None else self.clock() - t0
            for idx, spec in self._specs(FaultKind.PARTITION):
                if spec.at_step is not None:
                    continue
                if not (spec.matches_endpoint(task_id)
                        and spec.matches_attempt(attempt)):
                    continue
                in_window = spec.after_s <= elapsed < spec.after_s + spec.duration_s
                key = (idx, task_id, attempt)
                if in_window and key not in self._partitioning:
                    if not self._eligible(idx, spec):
                        continue
                    self._partitioning.add(key)
                    self._fire(idx, spec, task=task_id, attempt=attempt,
                               src=spec.src or spec.task, dst=spec.dst,
                               duration_s=spec.duration_s)
                if in_window and key in self._partitioning:
                    return True
        return False

    def should_preempt(self, task_id: str, attempt: int) -> bool:
        """True once this task's container should be reclaimed mid-attempt
        (capacity-scheduler preemption without a competing job)."""
        if not self.enabled:
            return False
        with self._lock:
            t0 = self._task_start.get((task_id, attempt))
            if t0 is None:
                return False
            for idx, spec in self._specs(FaultKind.PREEMPT):
                if (spec.matches_task(task_id) and spec.matches_attempt(attempt)
                        and self._eligible(idx, spec)
                        and self.clock() - t0 >= spec.after_s):
                    self._fire(idx, spec, task=task_id, attempt=attempt)
                    return True
        return False

    # ------------------------------------------------------------------
    # Hook: the training loop (step-gated faults)

    def check_step(self, task_id: str, attempt: int, step: int) -> None:
        """Raise the planned fault when (task, attempt, step) matches a
        KILL_TASK or OOM spec, and delay the step when it falls inside a
        SLOW_STEP window (the straggler fault: slow, not dead). The ML
        program calls this once per step."""
        if not self.enabled:
            return
        delay = 0.0
        with self._lock:
            for idx, spec in self._specs(FaultKind.KILL_TASK):
                if spec.in_ckpt_write:   # fires from check_ckpt_write instead
                    continue
                if (spec.matches_task(task_id) and spec.matches_attempt(attempt)
                        and spec.at_step == step and self._eligible(idx, spec)):
                    self._fire(idx, spec, task=task_id, attempt=attempt,
                               step=step)
                    raise ChaosKill(
                        f"chaos: injected kill of {task_id} at "
                        f"attempt={attempt} step={step} (seed={self.plan.seed})")
            for idx, spec in self._specs(FaultKind.OOM):
                if (spec.matches_task(task_id) and spec.matches_attempt(attempt)
                        and spec.at_step == step and self._eligible(idx, spec)):
                    self._fire(idx, spec, task=task_id, attempt=attempt,
                               step=step, oom=True)
                    raise ChaosOOM(OOM_MESSAGE.format(nbytes=17_179_869_184))
            for idx, spec in self._specs(FaultKind.PARTITION):
                # step-gated partitions raise from the src side only, so a
                # single deterministic task observes the fault per step
                if spec.at_step is None:
                    continue
                if not (spec.matches_src(task_id)
                        and spec.matches_attempt(attempt)):
                    continue
                hi = spec.until_step if spec.until_step is not None else spec.at_step
                if (spec.at_step <= step <= hi and self._eligible(idx, spec)):
                    self._fire(idx, spec, task=task_id, attempt=attempt,
                               step=step, src=spec.src or spec.task,
                               dst=spec.dst)
                    raise ChaosPartition(
                        f"chaos: network partition {spec.src or spec.task} "
                        f"<-> {spec.dst or '*'} at attempt={attempt} "
                        f"step={step} (seed={self.plan.seed})")
            for idx, spec in self._specs(FaultKind.SLOW_STEP):
                if not (spec.matches_task(task_id)
                        and spec.matches_attempt(attempt)):
                    continue
                lo = spec.at_step if spec.at_step is not None else 0
                if step < lo or (spec.until_step is not None
                                 and step > spec.until_step):
                    continue
                delay += spec.delay_s
                key = (idx, task_id, attempt)
                if key not in self._slowing and self._eligible(idx, spec):
                    # one event per (task, attempt) entering the window; the
                    # delay itself applies to every step in range
                    self._slowing.add(key)
                    self._fire(idx, spec, task=task_id, attempt=attempt,
                               step=step, delay_s=spec.delay_s,
                               until_step=spec.until_step)
        if delay:
            # sleep OUTSIDE the lock: a straggler must not slow the other
            # tasks' chaos hooks, only itself
            self.sleep(delay)

    def check_ckpt_write(self, task_id: str, attempt: int, step: int) -> None:
        """Hook inside the async checkpoint writer, between staging and the
        COMMIT marker. Raises for KILL_TASK specs with ``in_ckpt_write=True``
        whose ``at_step`` matches the checkpoint step being written —
        simulating a task killed mid-background-write, the exact window the
        publish-after-commit rule protects."""
        if not self.enabled:
            return
        with self._lock:
            for idx, spec in self._specs(FaultKind.KILL_TASK):
                if not spec.in_ckpt_write:
                    continue
                if (spec.matches_task(task_id) and spec.matches_attempt(attempt)
                        and (spec.at_step is None or spec.at_step == step)
                        and self._eligible(idx, spec)):
                    self._fire(idx, spec, task=task_id, attempt=attempt,
                               step=step, in_ckpt_write=True)
                    raise ChaosKill(
                        f"chaos: injected kill of {task_id} inside the "
                        f"checkpoint write of step {step} at attempt={attempt} "
                        f"(seed={self.plan.seed})")


#: Shared no-op injector — the production default everywhere chaos threads
#: through. Empty plan => every hook returns immediately.
NO_CHAOS = FaultInjector(FaultPlan())
