"""Global cluster spec — the TF_CONFIG-shaped JSON the AM assembles from task
registrations and broadcasts to every TaskExecutor."""
from __future__ import annotations

import json
from dataclasses import dataclass


@dataclass(frozen=True)
class TaskAddress:
    task_type: str
    index: int
    host: str
    port: int

    @property
    def endpoint(self) -> str:
        return f"{self.host}:{self.port}"


def build_cluster_spec(addresses: list[TaskAddress]) -> dict[str, list[str]]:
    """{"worker": ["host:port", ...], "ps": [...]} ordered by task index."""
    spec: dict[str, list[TaskAddress]] = {}
    for a in addresses:
        spec.setdefault(a.task_type, []).append(a)
    return {
        t: [a.endpoint for a in sorted(addrs, key=lambda a: a.index)]
        for t, addrs in sorted(spec.items())
    }


def spec_world_size(cluster_spec: dict[str, list[str]]) -> int:
    """The *actual* world size of a broadcast spec. Under elastic resize this
    can be smaller than the job's configured instance counts — programs must
    rendezvous on and shard for this number, never the requested one."""
    return sum(len(v) for v in cluster_spec.values())


def spec_task_counts(cluster_spec: dict[str, list[str]]) -> dict[str, int]:
    """Actual per-task-type membership of a broadcast spec."""
    return {t: len(v) for t, v in cluster_spec.items()}


def task_env(cluster_spec: dict[str, list[str]], task_type: str, index: int,
             job_args: dict[str, str]) -> dict[str, str]:
    """Environment a TaskExecutor materializes before spawning the ML child
    process (TonY sets TF_CONFIG-equivalent variables)."""
    env = {
        "CLUSTER_SPEC": json.dumps(cluster_spec, sort_keys=True),
        "TF_CONFIG": json.dumps({
            "cluster": cluster_spec,
            "task": {"type": task_type, "index": index},
        }, sort_keys=True),
        "TASK_TYPE": task_type,
        "TASK_INDEX": str(index),
        "WORLD_SIZE": str(spec_world_size(cluster_spec)),
    }
    for k, v in job_args.items():
        env[f"JOB_ARG_{k.upper()}"] = str(v)
    return env
