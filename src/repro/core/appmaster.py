"""TonY ApplicationMaster.

Negotiates heterogeneous containers with the RM, launches a TaskExecutor per
container, assembles + broadcasts the global cluster spec once every task has
registered, monitors heartbeats, aggregates logs/UI/metrics, and — on any
task failure — tears the attempt down, re-negotiates containers and
relaunches (checkpoint restore is the ML program's side of the contract).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

from repro.core.cluster_spec import TaskAddress, build_cluster_spec
from repro.core.events import EventLog
from repro.core.resources import (
    Container,
    ContainerRequest,
    ContainerState,
    JobSpec,
    PortAllocator,
)
from repro.core.rm import AllocationError, ResourceManager
from repro.core.task_executor import (
    ApplicationMasterProtocol,
    JobContext,
    MLProgram,
    TaskExecutor,
)

HEARTBEAT_TIMEOUT_S = 5.0


@dataclass
class AttemptReport:
    attempt: int
    exit_statuses: dict[str, int] = field(default_factory=dict)
    cluster_spec: dict | None = None
    failed_tasks: list[str] = field(default_factory=list)
    duration_s: float = 0.0


@dataclass
class JobResult:
    app_id: str
    final_status: str                 # SUCCEEDED | FAILED
    attempts: list[AttemptReport]
    ui_url: str | None
    task_logs: dict[str, list[str]]
    metrics: dict[str, dict[str, float]]

    @property
    def succeeded(self) -> bool:
        return self.final_status == "SUCCEEDED"


class ApplicationMaster(ApplicationMasterProtocol):
    REGISTRATION_TIMEOUT_S = 60.0
    PREEMPTION_BACKOFF_S = 0.3

    def __init__(self, rm: ResourceManager, app_id: str, job: JobSpec,
                 ml_program: MLProgram, events: EventLog | None = None,
                 ports: PortAllocator | None = None,
                 workdir: str = ""):
        self.rm = rm
        self.app_id = app_id
        self.job = job
        self.ml_program = ml_program
        self.events = events or rm.events
        self.ports = ports or PortAllocator()
        self.workdir = workdir
        self.ui_url: str | None = None
        self.task_logs: dict[str, list[str]] = {}
        self.metrics: dict[str, dict[str, float]] = {}
        self._lock = threading.Lock()
        self._registrations: dict[str, tuple[TaskExecutor, TaskAddress]] = {}
        self._last_heartbeat: dict[str, float] = {}
        self._exits: dict[str, int] = {}
        self._all_registered = threading.Event()
        self._world_size = sum(t.instances for t in self.job.tasks.values())

    # ------------------------------------------------------------------
    # Executor-facing protocol

    def register_task(self, executor: TaskExecutor, addr: TaskAddress,
                      ui_port: int | None = None) -> None:
        with self._lock:
            self._registrations[executor.task_id] = (executor, addr)
            self._last_heartbeat[executor.task_id] = time.monotonic()
            if ui_port is not None:
                self.ui_url = f"http://{addr.host}:{ui_port}"
                self.events.emit("am", "ui_registered", url=self.ui_url)
            done = len(self._registrations) == self._world_size
        self.events.emit("am", "task_registered", task=executor.task_id,
                         endpoint=addr.endpoint)
        if done:
            self._all_registered.set()

    def heartbeat(self, task_id: str) -> None:
        with self._lock:
            self._last_heartbeat[task_id] = time.monotonic()

    def report_exit(self, task_id: str, status: int) -> None:
        with self._lock:
            self._exits[task_id] = status
        self.events.emit("am", "task_exit", task=task_id, status=status)

    # ------------------------------------------------------------------
    def run(self) -> JobResult:
        self.rm.set_app_state(self.app_id, "RUNNING")
        attempts: list[AttemptReport] = []
        for attempt in range(1, self.job.max_app_attempts + 1):
            report = self._run_attempt(attempt)
            attempts.append(report)
            if not report.failed_tasks:
                self.rm.set_app_state(self.app_id, "FINISHED")
                return JobResult(self.app_id, "SUCCEEDED", attempts,
                                 self.ui_url, self.task_logs, self.metrics)
            self.events.emit("am", "attempt_failed", attempt=attempt,
                             failed=report.failed_tasks)
            if any(s == 137 for s in report.exit_statuses.values()):
                # preempted by the scheduler: back off before renegotiating
                # instead of ping-ponging with the preemptor's gang request
                self.events.emit("am", "preemption_backoff", attempt=attempt)
                time.sleep(self.PREEMPTION_BACKOFF_S)
        self.rm.set_app_state(self.app_id, "FAILED")
        return JobResult(self.app_id, "FAILED", attempts, self.ui_url,
                         self.task_logs, self.metrics)

    # ------------------------------------------------------------------
    NEGOTIATION_TIMEOUT_S = 5.0
    NEGOTIATION_BACKOFF_S = 0.05

    def _negotiate_containers(self) -> dict[str, list[Container]]:
        """Heterogeneous resource requests: e.g. GPU containers for workers,
        CPU-only for parameter servers (paper §2.2).

        Gang semantics with backoff: under contention the AM keeps asking
        until the whole gang fits or the negotiation window expires — a
        queued job waits for resources instead of burning an attempt
        (the paper's 'resource contention' motivation)."""
        deadline = time.monotonic() + self.NEGOTIATION_TIMEOUT_S
        waited = False
        while True:
            allocated: dict[str, list[Container]] = {}
            try:
                for task_type, tspec in sorted(self.job.tasks.items()):
                    req = ContainerRequest(tspec.resource, tspec.node_label)
                    allocated[task_type] = self.rm.allocate_many(
                        self.app_id, req, tspec.instances)
                    self.events.emit("am", "containers_negotiated",
                                     task_type=task_type, count=tspec.instances,
                                     gpus=tspec.resource.gpus)
                if waited:
                    self.events.emit("am", "negotiation_unblocked")
                return allocated
            except AllocationError:
                for cs in allocated.values():
                    for c in cs:
                        self.rm.release(c.container_id)
                if time.monotonic() >= deadline:
                    raise
                if not waited:
                    self.events.emit("am", "negotiation_waiting")
                    waited = True
                # under contention, ask the scheduler to reclaim capacity
                # from over-share queues (capacity-scheduler preemption)
                for _, tspec in sorted(self.job.tasks.items()):
                    self.rm.try_preempt_for(
                        self.app_id,
                        ContainerRequest(tspec.resource, tspec.node_label),
                        count=tspec.instances)
                time.sleep(self.NEGOTIATION_BACKOFF_S)

    def _run_attempt(self, attempt: int) -> AttemptReport:
        t0 = time.monotonic()
        self._registrations.clear()
        self._exits.clear()
        self._all_registered.clear()

        try:
            containers = self._negotiate_containers()
        except AllocationError as e:
            self.events.emit("am", "allocation_failed", error=str(e))
            return AttemptReport(attempt, failed_tasks=["__allocation__"],
                                 duration_s=time.monotonic() - t0)

        ctx = JobContext(world_size=self._world_size, workdir=self.workdir)
        ctx.shared["attempt"] = attempt
        executors: list[TaskExecutor] = []
        worker_like = "worker" if "worker" in containers else sorted(containers)[0]
        for task_type, clist in sorted(containers.items()):
            for idx, container in enumerate(clist):
                self.rm.mark_running(container.container_id)
                ex = TaskExecutor(
                    task_type, idx, container, self, self.ml_program,
                    self.job.args, ctx, self.ports, self.events,
                    is_chief_worker=(task_type == worker_like and idx == 0))
                executors.append(ex)
        for ex in executors:
            ex.start()

        # registration barrier -> global cluster spec -> broadcast
        spec = None
        if self._all_registered.wait(self.REGISTRATION_TIMEOUT_S):
            with self._lock:
                addrs = [a for (_, a) in self._registrations.values()]
            spec = build_cluster_spec(addrs)
            self.events.emit("am", "cluster_spec_built",
                             spec_sizes={k: len(v) for k, v in spec.items()})
            for ex, _ in self._registrations.values():
                ex.deliver_cluster_spec(spec)
        else:
            self.events.emit("am", "registration_timeout")
            ctx.cancel.set()

        # monitor: heartbeats + exits
        failed: list[str] = []
        while True:
            with self._lock:
                n_exit = len(self._exits)
                any_fail = any(s != 0 for s in self._exits.values())
                stale = [tid for tid, ts in self._last_heartbeat.items()
                         if tid not in self._exits
                         and time.monotonic() - ts > HEARTBEAT_TIMEOUT_S]
            if any_fail or stale:
                ctx.cancel.set()   # teardown remaining tasks (paper §2.2)
                for tid in stale:
                    self.events.emit("am", "heartbeat_lost", task=tid)
            if n_exit == len(executors):
                break
            time.sleep(0.01)

        for ex in executors:
            ex.join(timeout=10.0)
            self.task_logs[f"a{attempt}/{ex.task_id}"] = list(ex.log_lines)
            if ex.metrics:
                self.metrics[f"a{attempt}/{ex.task_id}"] = dict(ex.metrics)

        with self._lock:
            exits = dict(self._exits)
        failed = sorted([tid for tid, s in exits.items() if s != 0]
                        + [tid for tid in self._last_heartbeat
                           if tid not in exits])

        for clist in containers.values():
            for c in clist:
                st = ContainerState.COMPLETED if not failed else ContainerState.FAILED
                self.rm.release(c.container_id, st)

        return AttemptReport(attempt, exits, spec, failed,
                             time.monotonic() - t0)
