"""TonY ApplicationMaster.

Negotiates heterogeneous containers with the RM, launches a TaskExecutor per
container, assembles + broadcasts the global cluster spec once every task has
registered, monitors heartbeats, aggregates logs/UI/metrics, and — on any
task failure — tears the attempt down, re-negotiates containers and
relaunches (checkpoint restore is the ML program's side of the contract).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

from repro.core.chaos import NO_CHAOS, FaultInjector
from repro.core.cluster_spec import TaskAddress, build_cluster_spec
from repro.core.events import EventLog
from repro.core.failures import (
    EXIT_PREEMPTED,
    FailureClass,
    RetryPolicy,
    TaskDiagnostics,
    diagnose_allocation_failure,
    diagnose_exit,
    diagnose_heartbeat_timeout,
)
from repro.core.resources import (
    Container,
    ContainerRequest,
    ContainerState,
    JobSpec,
    PortAllocator,
)
from repro.core.rm import AllocationError, ResourceManager
from repro.core.speculation import (
    SpeculationPolicy,
    SpeculationTracker,
    SpeculativeCopy,
    is_speculative_id,
)
from repro.core.task_executor import (
    ApplicationMasterProtocol,
    JobContext,
    MLProgram,
    TaskExecutor,
)

HEARTBEAT_TIMEOUT_S = 5.0


@dataclass
class AttemptReport:
    attempt: int
    exit_statuses: dict[str, int] = field(default_factory=dict)
    cluster_spec: dict | None = None
    failed_tasks: list[str] = field(default_factory=list)
    duration_s: float = 0.0
    # task_id -> attributed failure (exception type/message/traceback +
    # classification) for every entry in failed_tasks
    diagnostics: dict[str, TaskDiagnostics] = field(default_factory=dict)
    # checkpoint step this attempt was told to restore from (None = cold
    # start) and the last checkpoint it *completed* — the AM threads the
    # latter into the next attempt's resume_step so retries don't retrain
    # from step 0
    resume_step: int | None = None
    checkpoint_step: int | None = None
    # task_id -> node that hosted it (failure attribution + blacklisting);
    # includes speculative copies under their "task#copy" exec ids
    nodes: dict[str, str] = field(default_factory=dict)
    # speculative execution: tasks flagged as stragglers this attempt, and
    # primary task -> race outcome (won | cancelled | failed) for every
    # backup copy that was launched
    stragglers: list[str] = field(default_factory=list)
    speculation: dict[str, str] = field(default_factory=dict)
    # elastic gang resize: task_type -> members this attempt LAUNCHED with
    # vs. the configured target, plus task ids shed mid-attempt after INFRA
    # losses above the floor (the gang kept running without them)
    task_counts: dict[str, int] = field(default_factory=dict)
    target_counts: dict[str, int] = field(default_factory=dict)
    shed_tasks: list[str] = field(default_factory=list)

    @property
    def degraded(self) -> bool:
        """True when this attempt ran below the configured gang at any point
        (launched short and/or shed members mid-attempt)."""
        return bool(self.shed_tasks) or any(
            self.task_counts.get(t, n) < n
            for t, n in self.target_counts.items())

    def final_counts(self) -> dict[str, int]:
        """Per-task-type membership at the END of the attempt (launch counts
        minus mid-attempt sheds)."""
        out = dict(self.task_counts)
        for tid in self.shed_tasks:
            ttype = tid.split(":")[0]
            out[ttype] = max(0, out.get(ttype, 0) - 1)
        return out


@dataclass
class JobResult:
    app_id: str
    final_status: str                 # SUCCEEDED | FAILED
    attempts: list[AttemptReport]
    ui_url: str | None
    task_logs: dict[str, list[str]]
    metrics: dict[str, dict[str, float]]
    # "a<attempt>/<task_id>" -> TaskDiagnostics, across every attempt
    diagnostics: dict[str, TaskDiagnostics] = field(default_factory=dict)
    # nodes the RM blacklisted while this job ran (NodeHealthTracker)
    blacklisted_nodes: list[str] = field(default_factory=list)

    @property
    def succeeded(self) -> bool:
        return self.final_status == "SUCCEEDED"

    @property
    def resumed_attempts(self) -> dict[int, int]:
        """attempt number -> checkpoint step it resumed from (warm starts)."""
        return {r.attempt: r.resume_step for r in self.attempts
                if r.resume_step is not None}

    @property
    def speculation(self) -> dict[str, str]:
        """"a<attempt>/<task>" -> race outcome (won/cancelled/failed) for
        every speculative backup launched across attempts."""
        return {f"a{r.attempt}/{t}": o for r in self.attempts
                for t, o in r.speculation.items()}

    @property
    def resized_attempts(self) -> dict[int, dict[str, int]]:
        """attempt number -> final per-task-type membership, for every
        attempt that ran degraded (elastic gang resize)."""
        return {r.attempt: r.final_counts() for r in self.attempts
                if r.degraded}

    def failure_summary(self) -> list[str]:
        """Human-readable one-liner per attributed failure, in attempt order."""
        return [f"{key}: [{d.classification.value}] "
                + (f"{d.exception_type}: {d.message}" if d.exception_type
                   else f"exit status {d.exit_status}")
                for key, d in sorted(self.diagnostics.items())]


class ApplicationMaster(ApplicationMasterProtocol):
    REGISTRATION_TIMEOUT_S = 60.0
    PREEMPTION_BACKOFF_S = 0.3

    def __init__(self, rm: ResourceManager, app_id: str, job: JobSpec,
                 ml_program: MLProgram, events: EventLog | None = None,
                 ports: PortAllocator | None = None,
                 workdir: str = "",
                 retry_policy: RetryPolicy | None = None,
                 chaos: FaultInjector | None = None,
                 speculation: SpeculationPolicy | None = None):
        self.rm = rm
        self.app_id = app_id
        self.job = job
        self.ml_program = ml_program
        self.events = events or rm.events
        self.ports = ports or PortAllocator()
        self.workdir = workdir
        # one injector threads through RM -> AM -> executors -> ML program;
        # default to the RM's (NO_CHAOS unless a chaos plan was installed)
        self.chaos = chaos or getattr(rm, "chaos", None) or NO_CHAOS
        self.retry_policy = retry_policy or RetryPolicy(
            max_attempts=job.max_app_attempts)
        # straggler detection + speculative backups (disabled by default)
        self.speculation = speculation or SpeculationPolicy()
        self.heartbeat_timeout_s = HEARTBEAT_TIMEOUT_S
        self.ui_url: str | None = None
        self.task_logs: dict[str, list[str]] = {}
        self.metrics: dict[str, dict[str, float]] = {}
        self._lock = threading.Lock()
        self._registrations: dict[str, tuple[TaskExecutor, TaskAddress]] = {}
        self._last_heartbeat: dict[str, float] = {}
        self._progress: dict[str, int] = {}      # exec_id -> latest step
        self._exits: dict[str, int] = {}
        self._exit_diagnostics: dict[str, TaskDiagnostics] = {}
        self._stale_tasks: dict[str, TaskDiagnostics] = {}
        self._all_registered = threading.Event()
        # configured gang width; each attempt's *actual* width may be
        # smaller under elastic resize (set per attempt in _expected_world)
        self._target_world = sum(t.instances for t in self.job.tasks.values())
        self._expected_world = self._target_world
        # previous attempt's launch width + degraded flag, to emit
        # gang_regrown when a later attempt recovers capacity
        self._prev_world: int | None = None
        self._prev_degraded = False

    # ------------------------------------------------------------------
    # Executor-facing protocol

    def register_task(self, executor: TaskExecutor, addr: TaskAddress,
                      ui_port: int | None = None) -> None:
        with self._lock:
            self._registrations[executor.task_id] = (executor, addr)
            self._last_heartbeat[executor.task_id] = time.monotonic()
            if ui_port is not None:
                self.ui_url = f"http://{addr.host}:{ui_port}"
                self.events.emit("am", "ui_registered", url=self.ui_url)
            done = len(self._registrations) == self._expected_world
        self.events.emit("am", "task_registered", task=executor.task_id,
                         endpoint=addr.endpoint)
        if done:
            self._all_registered.set()

    def heartbeat(self, task_id: str, progress: int | None = None) -> None:
        with self._lock:
            self._last_heartbeat[task_id] = time.monotonic()
            if progress is not None:
                self._progress[task_id] = progress

    def report_exit(self, task_id: str, status: int,
                    diagnostics: TaskDiagnostics | None = None) -> None:
        with self._lock:
            self._exits[task_id] = status
            if diagnostics is not None:
                self._exit_diagnostics[task_id] = diagnostics
        self.events.emit("am", "task_exit", task=task_id, status=status)

    # ------------------------------------------------------------------
    def run(self) -> JobResult:
        self.rm.set_app_state(self.app_id, "RUNNING")
        policy = self.retry_policy
        attempts: list[AttemptReport] = []
        diagnostics: dict[str, TaskDiagnostics] = {}
        attempt = 0
        resume_step: int | None = None
        while True:
            attempt += 1
            report = self._run_attempt(attempt, resume_step)
            attempts.append(report)
            # checkpoint-aware recovery: the next attempt restores from the
            # deepest checkpoint any attempt completed, not from step 0
            if report.checkpoint_step is not None:
                resume_step = max(resume_step or 0, report.checkpoint_step)
            for task_id, diag in report.diagnostics.items():
                diagnostics[f"a{attempt}/{task_id}"] = diag
            if not report.failed_tasks:
                self.rm.set_app_state(self.app_id, "FINISHED")
                return JobResult(self.app_id, "SUCCEEDED", attempts,
                                 self.ui_url, self.task_logs, self.metrics,
                                 diagnostics,
                                 blacklisted_nodes=self.rm.health.blacklisted(
                                     scope=self.job.queue))
            self.events.emit("am", "attempt_failed", attempt=attempt,
                             failed=report.failed_tasks)
            classes = {d.classification for d in report.diagnostics.values()}
            self.events.emit(
                "am", "attempt_classified", attempt=attempt,
                classes=sorted(c.value for c in classes),
                failures={t: d.describe()
                          for t, d in report.diagnostics.items()})
            decision = policy.decide(attempt, classes)
            if not decision.retry:
                self.events.emit("am", "retry_abandoned", attempt=attempt,
                                 reason=decision.reason)
                break
            backoff = decision.backoff_s
            if any(s == EXIT_PREEMPTED for s in report.exit_statuses.values()):
                # preempted by the scheduler: back off at least the preemption
                # grace instead of ping-ponging with the preemptor's gang ask
                backoff = max(backoff, self.PREEMPTION_BACKOFF_S)
                self.events.emit("am", "preemption_backoff", attempt=attempt)
            self.events.emit("am", "retry_scheduled", attempt=attempt,
                             next_attempt=attempt + 1, backoff_s=backoff,
                             reason=decision.reason)
            policy.sleep(backoff)
        self.rm.set_app_state(self.app_id, "FAILED")
        return JobResult(self.app_id, "FAILED", attempts, self.ui_url,
                         self.task_logs, self.metrics, diagnostics,
                         blacklisted_nodes=self.rm.health.blacklisted(
                             scope=self.job.queue))

    # ------------------------------------------------------------------
    NEGOTIATION_TIMEOUT_S = 5.0
    NEGOTIATION_BACKOFF_S = 0.05
    # once this fraction of the negotiation window has burned without the
    # full gang fitting, an elastic job downsizes toward its floors instead
    # of waiting out the rest of the window and dying
    ELASTIC_SHRINK_FRACTION = 0.5

    def _negotiate_containers(self, attempt: int = 0) -> dict[str, list[Container]]:
        """Heterogeneous resource requests: e.g. GPU containers for workers,
        CPU-only for parameter servers (paper §2.2).

        Gang semantics with backoff: under contention the AM keeps asking
        until the whole gang fits or the negotiation window expires — a
        queued job waits for resources instead of burning an attempt
        (the paper's 'resource contention' motivation).

        Elastic jobs (any task with min_instances < instances) degrade
        instead of dying: past ELASTIC_SHRINK_FRACTION of the window the AM
        retries with ``allocate_up_to`` down to each task's floor, emitting
        ``gang_resized`` per shrunk type. Every attempt asks for the FULL
        gang first, so a later attempt regrows automatically once capacity
        returns (e.g. after node parole)."""
        deadline = time.monotonic() + self.NEGOTIATION_TIMEOUT_S
        shrink_at = (time.monotonic()
                     + self.NEGOTIATION_TIMEOUT_S * self.ELASTIC_SHRINK_FRACTION)
        elastic = any(t.elastic for t in self.job.tasks.values())
        waited = False
        while True:
            allocated: dict[str, list[Container]] = {}
            try:
                for task_type, tspec in sorted(self.job.tasks.items()):
                    req = ContainerRequest(tspec.resource, tspec.node_label)
                    allocated[task_type] = self.rm.allocate_many(
                        self.app_id, req, tspec.instances)
                    self.events.emit("am", "containers_negotiated",
                                     task_type=task_type, count=tspec.instances,
                                     gpus=tspec.resource.gpus)
                if waited:
                    self.events.emit("am", "negotiation_unblocked")
                return allocated
            except AllocationError:
                for cs in allocated.values():
                    for c in cs:
                        self.rm.release(c.container_id)
                if elastic and time.monotonic() >= shrink_at:
                    degraded = self._negotiate_degraded(attempt)
                    if degraded is not None:
                        return degraded
                if time.monotonic() >= deadline:
                    raise
                if not waited:
                    self.events.emit("am", "negotiation_waiting")
                    waited = True
                # under contention, ask the scheduler to reclaim capacity
                # from over-share queues (capacity-scheduler preemption)
                for _, tspec in sorted(self.job.tasks.items()):
                    self.rm.try_preempt_for(
                        self.app_id,
                        ContainerRequest(tspec.resource, tspec.node_label),
                        count=tspec.instances)
                time.sleep(self.NEGOTIATION_BACKOFF_S)

    def _negotiate_degraded(self, attempt: int) -> dict[str, list[Container]] | None:
        """One best-effort pass: rigid tasks still demand their full width,
        elastic tasks accept anything down to their floor. Returns None
        (releasing everything) when even the floors don't fit — the caller
        keeps waiting out the negotiation window."""
        allocated: dict[str, list[Container]] = {}
        try:
            for task_type, tspec in sorted(self.job.tasks.items()):
                req = ContainerRequest(tspec.resource, tspec.node_label)
                if tspec.elastic:
                    allocated[task_type] = self.rm.allocate_up_to(
                        self.app_id, req, tspec.instances,
                        minimum=tspec.floor)
                else:
                    allocated[task_type] = self.rm.allocate_many(
                        self.app_id, req, tspec.instances)
        except AllocationError:
            for cs in allocated.values():
                for c in cs:
                    self.rm.release(c.container_id)
            return None
        for task_type, cs in sorted(allocated.items()):
            tspec = self.job.tasks[task_type]
            if len(cs) < tspec.instances:
                self.events.emit("am", "gang_resized", attempt=attempt,
                                 task_type=task_type,
                                 reason="allocation_shortfall",
                                 from_count=tspec.instances,
                                 to_count=len(cs), floor=tspec.floor)
            self.events.emit("am", "containers_negotiated",
                             task_type=task_type, count=len(cs),
                             gpus=tspec.resource.gpus)
        return allocated

    def _run_attempt(self, attempt: int,
                     resume_step: int | None = None) -> AttemptReport:
        t0 = time.monotonic()
        self._registrations.clear()
        self._last_heartbeat.clear()
        self._exits.clear()
        self._exit_diagnostics.clear()
        self._stale_tasks.clear()
        self._progress.clear()
        self._all_registered.clear()

        try:
            containers = self._negotiate_containers(attempt)
        except AllocationError as e:
            self.events.emit("am", "allocation_failed", error=str(e))
            diag = diagnose_allocation_failure(str(e))
            self.events.emit("am", "task_failed", attempt=attempt,
                             task="__allocation__",
                             classification=diag.classification.value,
                             reason=diag.message)
            return AttemptReport(attempt, failed_tasks=["__allocation__"],
                                 duration_s=time.monotonic() - t0,
                                 diagnostics={"__allocation__": diag},
                                 resume_step=resume_step)

        # elastic resize bookkeeping: the attempt's ACTUAL gang vs. target.
        # _expected_world gates the registration barrier, so it must be set
        # before any executor starts.
        counts = {t: len(cs) for t, cs in containers.items()}
        targets = {t: s.instances for t, s in self.job.tasks.items()}
        world = sum(counts.values())
        with self._lock:
            self._expected_world = world
        if world < self._target_world:
            self.events.emit("am", "attempt_degraded", attempt=attempt,
                             world_size=world, target_world=self._target_world,
                             task_counts=dict(counts))
        elif self._prev_degraded and self._prev_world is not None \
                and world > self._prev_world:
            self.events.emit("am", "gang_regrown", attempt=attempt,
                             world_size=world, from_world=self._prev_world,
                             task_counts=dict(counts))
        self._prev_world = world
        self._prev_degraded = world < self._target_world

        ctx = JobContext(world_size=world, workdir=self.workdir,
                         chaos=self.chaos, events=self.events)
        ctx.shared["attempt"] = attempt
        ctx.shared["world_size"] = world
        ctx.shared["target_world"] = self._target_world
        ctx.shared["task_counts"] = dict(counts)
        ctx.shared["target_counts"] = dict(targets)
        if resume_step is not None:
            # the relaunched program restores from this checkpoint instead
            # of reinitializing (checkpoint/checkpointer.py is its side of
            # the contract)
            ctx.shared["resume_step"] = resume_step
            self.events.emit("am", "attempt_resumed", attempt=attempt,
                             resume_step=resume_step)
        executors: list[TaskExecutor] = []
        worker_like = "worker" if "worker" in containers else sorted(containers)[0]
        for task_type, clist in sorted(containers.items()):
            for idx, container in enumerate(clist):
                self.rm.mark_running(container.container_id)
                ex = TaskExecutor(
                    task_type, idx, container, self, self.ml_program,
                    self.job.args, ctx, self.ports, self.events,
                    is_chief_worker=(task_type == worker_like and idx == 0),
                    chaos=self.chaos)
                executors.append(ex)
        for ex in executors:
            ex.start()

        # registration barrier -> global cluster spec -> broadcast
        spec = None
        if self._all_registered.wait(self.REGISTRATION_TIMEOUT_S):
            with self._lock:
                addrs = [a for (_, a) in self._registrations.values()]
            spec = build_cluster_spec(addrs)
            self.events.emit("am", "cluster_spec_built",
                             spec_sizes={k: len(v) for k, v in spec.items()})
            for ex, _ in self._registrations.values():
                ex.deliver_cluster_spec(spec)
        else:
            self.events.emit("am", "registration_timeout")
            ctx.cancel.set()

        # monitor: heartbeats + exits + straggler detection
        tracker = SpeculationTracker(self.speculation)
        spec_copies: dict[str, SpeculativeCopy] = {}   # primary id -> copy
        forgiven: set[str] = set()   # exec ids whose nonzero exit is benign
        stragglers: list[str] = []
        exec_by_id = {ex.task_id: ex for ex in executors}
        # elastic mid-attempt shed: INFRA-lost members of an elastic task
        # type, above its floor and not the chief, leave the gang instead of
        # tearing the attempt down
        shed: set[str] = set()
        shed_diags: dict[str, TaskDiagnostics] = {}
        live_counts = dict(counts)
        chief_id = f"{worker_like}:0"
        while True:
            with self._lock:
                exits = dict(self._exits)
                progress = dict(self._progress)
                stale = [tid for tid, ts in self._last_heartbeat.items()
                         if tid not in self._exits
                         and time.monotonic() - ts > self.heartbeat_timeout_s]

            # resolve speculation races: first finisher of the (primary,
            # copy) pair wins; the loser is torn down as a TRANSIENT loser
            # and its exit never fails the attempt or strikes its node
            for tid, copy in spec_copies.items():
                if copy.outcome:
                    continue
                p, s = exits.get(tid), exits.get(copy.exec_id)
                if p == 0:
                    copy.outcome = "cancelled"
                    forgiven.add(copy.exec_id)
                    copy.executor.cancel.set()
                    self.events.emit("am", "speculative_cancelled",
                                     task=tid, exec_id=copy.exec_id,
                                     attempt=attempt,
                                     reason="original finished first")
                elif s == 0:
                    copy.outcome = "won"
                    forgiven.add(tid)
                    if p is None:
                        exec_by_id[tid].cancel.set()
                    self.events.emit("am", "speculative_won",
                                     task=tid, exec_id=copy.exec_id,
                                     attempt=attempt,
                                     node=copy.container.node_id)
                elif s is not None:
                    # the backup died (nonzero): keep the original running —
                    # a failed backup alone never fails the attempt
                    copy.outcome = "failed"
                    forgiven.add(copy.exec_id)
                    self.events.emit("am", "speculative_cancelled",
                                     task=tid, exec_id=copy.exec_id,
                                     attempt=attempt,
                                     reason=f"speculative copy failed "
                                            f"(exit {s}); original continues")

            # straggler detection: compare each primary's heartbeat progress
            # to the gang median; after `patience` consecutive lagging
            # observations, launch a backup copy on a different node
            if self.speculation.enabled and spec is not None \
                    and not ctx.cancel.is_set():
                gang = {t: p for t, p in progress.items()
                        if not is_speculative_id(t)}
                for tid in tracker.observe(gang):
                    if tid in exits or tid in spec_copies:
                        continue
                    stragglers.append(tid)
                    self.events.emit(
                        "am", "straggler_detected", task=tid, attempt=attempt,
                        progress=gang.get(tid), median=tracker.last_median,
                        factor=self.speculation.slowdown_factor,
                        patience=self.speculation.patience)
                    copy = self._launch_speculative(exec_by_id[tid], spec,
                                                    ctx, attempt)
                    if copy is not None:
                        spec_copies[tid] = copy
                        tracker.note_launched()

            # elastic shed: an INFRA death of a non-chief member of an
            # elastic task type, while the type is still above its floor,
            # removes the task from the gang (barrier shrinks, node is
            # charged, container released) and the attempt continues —
            # degrade instead of die. Chief losses and TRANSIENT/FATAL_USER
            # exits still tear the attempt down.
            for xid, s in exits.items():
                if s == 0 or xid in forgiven or xid in shed \
                        or is_speculative_id(xid) or xid in spec_copies:
                    continue
                tspec = self.job.tasks.get(xid.split(":")[0])
                if tspec is None or not tspec.elastic or xid == chief_id:
                    continue
                if live_counts.get(tspec.task_type, 0) - 1 < tspec.floor:
                    continue
                diag = (self._exit_diagnostics.get(xid)
                        or diagnose_exit(xid, s))
                if diag.classification is not FailureClass.INFRA:
                    continue
                shed.add(xid)
                shed_diags[xid] = diag
                live_counts[tspec.task_type] -= 1
                self.events.emit("am", "task_failed", attempt=attempt,
                                 task=xid,
                                 classification=diag.classification.value,
                                 reason=diag.describe())
                self.events.emit("am", "gang_resized", attempt=attempt,
                                 task_type=tspec.task_type,
                                 reason="infra_loss", shed_task=xid,
                                 from_count=live_counts[tspec.task_type] + 1,
                                 to_count=live_counts[tspec.task_type],
                                 floor=tspec.floor)
                ex = exec_by_id.get(xid)
                if ex is not None:
                    self.rm.report_node_failure(ex.container.node_id, diag,
                                                queue=self.job.queue)
                    self.rm.release(ex.container.container_id,
                                    ContainerState.FAILED, exit_status=s)
                ctx.shrink_world()

            # a primary's nonzero exit is excused when its backup won (or is
            # still racing); a copy's exit never tears the gang down — and a
            # shed elastic member's exit is already accounted for
            real_failed = False
            for xid, s in exits.items():
                if s == 0 or xid in forgiven or xid in shed \
                        or is_speculative_id(xid):
                    continue
                copy = spec_copies.get(xid)
                if copy is not None:
                    cs = exits.get(copy.exec_id)
                    if copy.outcome == "won" or cs == 0 or \
                            (cs is None and copy.outcome == ""):
                        continue
                real_failed = True
            if real_failed or stale:
                ctx.cancel.set()   # teardown remaining tasks (paper §2.2)
                for tid in stale:
                    if tid not in self._stale_tasks:
                        # a lost heartbeat is a classified failure, not just
                        # a log line: record it so the retry policy and the
                        # history server can attribute the attempt's death
                        self._stale_tasks[tid] = diagnose_heartbeat_timeout(
                            tid, self.heartbeat_timeout_s)
                        self.events.emit("am", "heartbeat_lost", task=tid)
            if len(exits) == len(executors) + len(spec_copies):
                break
            time.sleep(0.01)

        # races left undecided when the attempt ended: tear the copies down
        for tid, copy in spec_copies.items():
            if not copy.outcome:
                copy.outcome = "cancelled"
                forgiven.add(copy.exec_id)
                copy.executor.cancel.set()
                self.events.emit("am", "speculative_cancelled",
                                 task=tid, exec_id=copy.exec_id,
                                 attempt=attempt, reason="attempt torn down")

        all_execs = executors + [c.executor for c in spec_copies.values()]
        for ex in all_execs:
            ex.join(timeout=10.0)
            self.task_logs[f"a{attempt}/{ex.exec_id}"] = list(ex.log_lines)
            if ex.metrics:
                self.metrics[f"a{attempt}/{ex.exec_id}"] = dict(ex.metrics)

        with self._lock:
            exits = dict(self._exits)
            exit_diags = dict(self._exit_diagnostics)
        won = {tid for tid, c in spec_copies.items() if c.outcome == "won"}
        # a task that tripped the heartbeat timeout counts as failed even if
        # its child squeaked out a clean exit after the teardown began — the
        # node was presumed lost and the attempt was already torn down
        # (otherwise the 143-vs-0 teardown race can mislabel the attempt).
        # Speculation carve-outs: a primary whose backup won is not failed,
        # and a copy's own exit never makes this list (its failure is the
        # race outcome, not the attempt's).
        # A shed elastic member's death was absorbed mid-attempt (gang
        # shrank instead of dying), so it never fails the attempt here.
        failed = sorted(set(
            [tid for tid, s in exits.items()
             if s != 0 and tid not in won and tid not in forgiven
             and tid not in shed and not is_speculative_id(tid)]
            + [tid for tid in self._last_heartbeat
               if tid not in exits and not is_speculative_id(tid)
               and tid not in won]
            + [tid for tid in self._stale_tasks
               if not is_speculative_id(tid) and tid not in won
               and tid not in shed]))

        # attribute every failure: a child exception beats a heartbeat
        # timeout beats a bare exit code
        node_of = {ex.task_id: ex.container.node_id for ex in executors}
        diagnostics: dict[str, TaskDiagnostics] = {}
        for tid in failed:
            diag = (exit_diags.get(tid) or self._stale_tasks.get(tid)
                    or diagnose_exit(tid, exits.get(tid, -1)))
            diagnostics[tid] = diag
            self.events.emit("am", "task_failed", attempt=attempt, task=tid,
                             classification=diag.classification.value,
                             reason=diag.describe())
            # charge INFRA failures to the hosting node so the RM can
            # blacklist hosts that keep killing tasks (OOM, preemption
            # storms); speculation losers never reach here, so a slow-but-
            # alive node is never struck for losing a race
            if tid in node_of:
                self.rm.report_node_failure(node_of[tid], diag,
                                            queue=self.job.queue)
        if not failed:
            # a clean attempt wipes strikes — except on nodes that hosted a
            # shed member: their INFRA charge must survive the gang's
            # success, or a flaky host never accumulates toward blacklist
            shed_nodes = {node_of[t] for t in shed if t in node_of}
            for node in set(node_of.values()) - shed_nodes:
                self.rm.report_node_success(node, queue=self.job.queue)

        st = ContainerState.COMPLETED if not failed else ContainerState.FAILED
        for clist in containers.values():
            for c in clist:
                self.rm.release(c.container_id, st)
        for copy in spec_copies.values():
            self.rm.release(copy.container.container_id, st)

        nodes_report = dict(node_of)
        nodes_report.update({c.exec_id: c.container.node_id
                             for c in spec_copies.values()})

        # shed members' attributed failures ride along in the report (they
        # didn't fail the attempt, but post-mortems must still see them)
        for tid, diag in shed_diags.items():
            diagnostics.setdefault(tid, diag)

        # the chief publishes each completed checkpoint into the shared dict;
        # whatever survived this attempt seeds the next one's resume_step
        ckpt_step = ctx.shared.get("ckpt_step")
        return AttemptReport(attempt, exits, spec, failed,
                             time.monotonic() - t0, diagnostics,
                             resume_step=resume_step,
                             checkpoint_step=(int(ckpt_step)
                                              if ckpt_step is not None else None),
                             nodes=nodes_report,
                             stragglers=stragglers,
                             speculation={tid: c.outcome
                                          for tid, c in spec_copies.items()},
                             task_counts=counts,
                             target_counts=targets,
                             shed_tasks=sorted(shed))

    def _launch_speculative(self, primary: TaskExecutor, cluster_spec: dict,
                            ctx: JobContext,
                            attempt: int) -> SpeculativeCopy | None:
        """Allocate a container off the straggler's node and start a backup
        copy of the task. The copy skips registration (the gang spec is
        pre-delivered) and the program skips rendezvous (env SPECULATIVE=1).
        Returns None when the RM has no eligible capacity."""
        tspec = self.job.tasks[primary.task_type]
        req = ContainerRequest(tspec.resource, tspec.node_label)
        try:
            container = self.rm.allocate(
                self.app_id, req,
                exclude_nodes={primary.container.node_id})
        except AllocationError as e:
            self.events.emit("am", "speculative_cancelled",
                             task=primary.task_id, exec_id="", attempt=attempt,
                             reason=f"backup allocation failed: {e}")
            return None
        self.rm.mark_running(container.container_id)
        ex = TaskExecutor(
            primary.task_type, primary.index, container, self,
            self.ml_program, self.job.args, ctx, self.ports, self.events,
            chaos=self.chaos, speculative=True)
        ex.deliver_cluster_spec(cluster_spec)
        ex.start()
        self.events.emit("am", "speculative_launched",
                         task=primary.task_id, exec_id=ex.exec_id,
                         attempt=attempt, node=container.node_id,
                         avoided_node=primary.container.node_id)
        return SpeculativeCopy(primary.task_id, ex.exec_id, ex, container)
