"""Event log shared by RM / AM / executors — the substrate for the history
server, metrics analyzer and tests (deterministic, inspectable)."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class Event:
    ts: float
    source: str       # rm | am | executor:<task> | client
    kind: str         # e.g. container_allocated, task_registered, heartbeat
    payload: dict[str, Any] = field(default_factory=dict)


#: Failure-diagnostics event kinds emitted by the AM (core/failures.py):
#:   task_failed        — one task's attributed failure (classification+reason)
#:   attempt_classified — the attempt's overall failure-class set
#:   retry_scheduled    — the policy granted a relaunch (backoff_s, reason)
#:   retry_abandoned    — the policy refused (fail-fast or budget exhausted)
FAILURE_EVENT_KINDS = frozenset({
    "task_failed", "attempt_classified", "retry_scheduled", "retry_abandoned",
})

#: Recovery / chaos event kinds (core/chaos.py, core/rm.py NodeHealthTracker):
#:   chaos_injected     — a planned fault fired (kind, seed, task/step info)
#:   attempt_resumed    — a relaunched attempt restored from a checkpoint
#:                        (resume_step) instead of cold-starting
#:   node_blacklisted   — K INFRA failures tipped a node out of placement
#:   node_paroled       — a blacklisted node's parole expired; allowed back
RECOVERY_EVENT_KINDS = frozenset({
    "chaos_injected", "attempt_resumed", "node_blacklisted", "node_paroled",
})

#: Speculative-execution event kinds (core/speculation.py, emitted by the AM):
#:   straggler_detected    — a task fell behind the gang median for the
#:                           policy's patience window (progress, median)
#:   speculative_launched  — a backup copy was started on another node
#:   speculative_won       — the backup finished first; the original was
#:                           torn down as a TRANSIENT loser
#:   speculative_cancelled — the backup was torn down (original finished
#:                           first, backup failed, allocation denied, or the
#:                           attempt ended with the race undecided)
SPECULATION_EVENT_KINDS = frozenset({
    "straggler_detected", "speculative_launched", "speculative_won",
    "speculative_cancelled",
})

#: Elastic gang-resize event kinds (core/appmaster.py, core/rm.py):
#:   partial_allocation — the RM granted fewer containers than asked but at
#:                        least the caller's minimum (allocate_up_to)
#:   gang_resized       — the AM shrank a task type below its configured
#:                        width (reason: allocation_shortfall at negotiation
#:                        time, or infra_loss for a mid-attempt shed)
#:   attempt_degraded   — an attempt launched with world_size < target_world
#:   gang_regrown       — a later attempt recovered capacity and launched
#:                        wider than the previous (degraded) one
ELASTIC_EVENT_KINDS = frozenset({
    "partial_allocation", "gang_resized", "attempt_degraded", "gang_regrown",
})

#: Checkpoint event kinds (checkpoint/checkpointer.py via the train program):
#:   ckpt_committed — a checkpoint's atomic rename landed (step, duration_s,
#:                    bytes, async flag). Emitted only AFTER commit — by the
#:                    background writer on the async path — so the event
#:                    trail, like ``ctx.shared["ckpt_step"]``, never names a
#:                    step a relaunch couldn't resume from.
CHECKPOINT_EVENT_KINDS = frozenset({"ckpt_committed"})


class EventLog:
    def __init__(self):
        self._events: list[Event] = []
        self._lock = threading.Lock()

    def emit(self, source: str, kind: str, **payload: Any) -> Event:
        ev = Event(time.monotonic(), source, kind, payload)
        with self._lock:
            self._events.append(ev)
        return ev

    def all(self) -> list[Event]:
        with self._lock:
            return list(self._events)

    def of_kind(self, kind: str) -> list[Event]:
        return [e for e in self.all() if e.kind == kind]

    def count(self, kind: str) -> int:
        return len(self.of_kind(kind))

    def failure_timeline(self) -> list[Event]:
        """All failure-diagnostics + recovery + speculation + elastic-resize
        events in order — the 'why did my job fail (and how did it come
        back)' trail the history server renders."""
        return [e for e in self.all()
                if e.kind in FAILURE_EVENT_KINDS
                or e.kind in RECOVERY_EVENT_KINDS
                or e.kind in SPECULATION_EVENT_KINDS
                or e.kind in ELASTIC_EVENT_KINDS
                or e.kind in CHECKPOINT_EVENT_KINDS]
