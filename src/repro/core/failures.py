"""Failure diagnostics + retry policy — the orchestrator's answer to
"why did my job fail, and was retrying it ever going to help?".

Three pieces:

* ``TaskDiagnostics`` — what one task's failure looked like (exception type,
  message, formatted traceback, exit status) plus a classification.
* ``FailureClass`` — FATAL_USER (broken user code: retrying burns cluster
  time and can never succeed), TRANSIENT (injected faults, heartbeat
  timeouts, allocation contention: retry with backoff), INFRA (RM/container
  trouble such as preemption or executor-side errors: retry, the cluster may
  recover).
* ``RetryPolicy`` — attempt budget + exponential backoff with an injectable
  sleep so tests run on a fake clock, and fail-fast classes that abort the
  retry loop immediately.

The AM consults the policy between attempts; TaskExecutors produce the
diagnostics; the history server and metrics analyzer surface them.
"""
from __future__ import annotations

import re
import time
import traceback as _tb
from dataclasses import dataclass, field, replace
from enum import Enum
from typing import Callable, Iterable


class FailureClass(Enum):
    FATAL_USER = "FATAL_USER"   # bad user code — never worth retrying
    TRANSIENT = "TRANSIENT"     # flaky env / injected fault — retry w/ backoff
    INFRA = "INFRA"             # RM / container / executor trouble — retry

    def __str__(self) -> str:  # event payloads + summaries read naturally
        return self.value


#: Exception types that indicate the user's program itself is broken; no
#: number of relaunches will fix a module that doesn't import or a name that
#: doesn't resolve.
FATAL_USER_EXCEPTIONS = frozenset({
    "ImportError", "ModuleNotFoundError", "AttributeError", "NameError",
    "SyntaxError", "IndentationError", "NotImplementedError",
})

#: Container exit codes with a known infra meaning (YARN conventions).
EXIT_PREEMPTED = 137        # SIGKILL by the scheduler
EXIT_TEARDOWN = 143         # SIGTERM by the AM (sibling failed / cancel)
EXIT_EXECUTOR_ERROR = 2     # the executor itself (not the child) broke
EXIT_SPECULATION_LOST = 140  # torn down after losing a speculation race

#: Exception types that mean the process ran out of memory outright.
OOM_EXCEPTION_TYPES = frozenset({"MemoryError", "ChaosOOM"})

#: Message signatures of allocator exhaustion: XLA's RESOURCE_EXHAUSTED
#: status, CUDA's OOM error, and the generic phrasing JAX/TF surface them
#: with. Matched case-insensitively against the exception message.
_OOM_MESSAGE_PATTERNS = re.compile(
    r"RESOURCE_EXHAUSTED|CUDA_ERROR_OUT_OF_MEMORY|out of memory|"
    r"failed to allocate .* memory|OOM when allocating", re.IGNORECASE)


def is_oom_signature(exception_type: str, message: str = "") -> bool:
    """Does (type, message) look like the task died of memory exhaustion?"""
    if exception_type in OOM_EXCEPTION_TYPES:
        return True
    return bool(message) and _OOM_MESSAGE_PATTERNS.search(message) is not None


@dataclass(frozen=True)
class TaskDiagnostics:
    """One task's failure, attributed. ``traceback`` is the full formatted
    traceback when the failure was an exception in the child program."""
    task_id: str
    exit_status: int
    classification: FailureClass
    exception_type: str = ""
    message: str = ""
    traceback: str = ""
    # the task died of memory exhaustion (MemoryError / RESOURCE_EXHAUSTED);
    # INFRA-classified, and the node-health tracker + analyzer key off it
    oom: bool = False

    def to_dict(self) -> dict:
        return {
            "task_id": self.task_id,
            "exit_status": self.exit_status,
            "classification": self.classification.value,
            "exception_type": self.exception_type,
            "message": self.message,
            "traceback": self.traceback,
            "oom": self.oom,
        }

    def describe(self) -> str:
        head = f"{self.task_id}: [{self.classification.value}]"
        tail = " (OOM)" if self.oom else ""
        if self.exception_type:
            return f"{head} {self.exception_type}: {self.message}{tail}"
        return f"{head} exit status {self.exit_status}{tail}"


def classify_exception(exc: BaseException | str,
                       message: str = "") -> FailureClass:
    """Map a child-program exception (or its type name + message) to a
    failure class. OOM signatures are INFRA: the *node* ran out of memory
    (or the container was sized wrong) — a reallocation elsewhere can
    succeed, and repeated OOMs on one host feed node blacklisting."""
    name = exc if isinstance(exc, str) else type(exc).__name__
    if not message and not isinstance(exc, str):
        message = str(exc)
    if is_oom_signature(name, message):
        return FailureClass.INFRA
    if name in FATAL_USER_EXCEPTIONS:
        return FailureClass.FATAL_USER
    return FailureClass.TRANSIENT


def classify_exit(status: int) -> FailureClass:
    """Classify a nonzero exit with no exception attached to it."""
    if status == EXIT_PREEMPTED or status == EXIT_EXECUTOR_ERROR:
        return FailureClass.INFRA
    return FailureClass.TRANSIENT


def diagnose_exception(task_id: str, exc: BaseException,
                       exit_status: int = 1) -> TaskDiagnostics:
    """Build diagnostics from a live exception (captures the traceback)."""
    name, msg = type(exc).__name__, str(exc)
    return TaskDiagnostics(
        task_id=task_id,
        exit_status=exit_status,
        classification=classify_exception(name, msg),
        exception_type=name,
        message=msg,
        traceback="".join(_tb.format_exception(type(exc), exc,
                                               exc.__traceback__)),
        oom=is_oom_signature(name, msg),
    )


def diagnose_exit(task_id: str, status: int) -> TaskDiagnostics:
    reasons = {
        EXIT_PREEMPTED: "container preempted by the scheduler",
        EXIT_TEARDOWN: "torn down by the AM (a sibling task failed or the "
                       "attempt was cancelled)",
        EXIT_EXECUTOR_ERROR: "task executor error (not the ML program)",
        EXIT_SPECULATION_LOST: "torn down after losing the speculative-"
                               "execution race (a faster copy of this task "
                               "finished first) — TRANSIENT, never charged "
                               "to the hosting node",
        3: "cancelled before the job rendezvoused",
    }
    return TaskDiagnostics(
        task_id=task_id, exit_status=status,
        classification=classify_exit(status),
        message=reasons.get(status, f"exited with status {status}"))


def diagnose_heartbeat_timeout(task_id: str, timeout_s: float) -> TaskDiagnostics:
    return TaskDiagnostics(
        task_id=task_id, exit_status=-1,
        classification=FailureClass.TRANSIENT,
        exception_type="HeartbeatTimeout",
        message=f"no heartbeat for more than {timeout_s:g}s; "
                "task presumed hung or its node lost")


def diagnose_allocation_failure(error: str) -> TaskDiagnostics:
    # Allocation failures are contention, not broken code: another attempt
    # may find capacity freed (classified TRANSIENT per the survey's
    # fault-tolerance taxonomy).
    return TaskDiagnostics(
        task_id="__allocation__", exit_status=-1,
        classification=FailureClass.TRANSIENT,
        exception_type="AllocationError", message=error)


@dataclass(frozen=True)
class RetryDecision:
    retry: bool
    reason: str
    backoff_s: float = 0.0


@dataclass(frozen=True)
class RetryPolicy:
    """Attempt budget + exponential backoff + fail-fast classes.

    ``sleep`` is injectable so tests drive the backoff on a fake clock; the
    default is the real ``time.sleep``.
    """
    max_attempts: int = 3
    base_backoff_s: float = 0.05
    backoff_multiplier: float = 2.0
    max_backoff_s: float = 2.0
    fail_fast_on: frozenset = frozenset({FailureClass.FATAL_USER})
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False,
                                           compare=False)

    def with_clock(self, sleep: Callable[[float], None]) -> "RetryPolicy":
        return replace(self, sleep=sleep)

    def backoff_for(self, attempt: int) -> float:
        """Backoff before relaunching after ``attempt`` (1-based) failed."""
        raw = self.base_backoff_s * self.backoff_multiplier ** (attempt - 1)
        return min(raw, self.max_backoff_s)

    def decide(self, attempt: int,
               classes: Iterable[FailureClass]) -> RetryDecision:
        classes = set(classes)
        fatal = classes & set(self.fail_fast_on)
        if fatal:
            return RetryDecision(
                False, "fail-fast: " + ", ".join(sorted(c.value for c in fatal))
                + " failures cannot succeed on retry")
        if attempt >= self.max_attempts:
            return RetryDecision(
                False, f"attempt budget exhausted ({self.max_attempts})")
        return RetryDecision(True, "retryable failure classes: "
                             + (", ".join(sorted(c.value for c in classes))
                                or "unknown"),
                             backoff_s=self.backoff_for(attempt))
