"""Failure diagnostics + retry policy — the orchestrator's answer to
"why did my job fail, and was retrying it ever going to help?".

Three pieces:

* ``TaskDiagnostics`` — what one task's failure looked like (exception type,
  message, formatted traceback, exit status) plus a classification.
* ``FailureClass`` — FATAL_USER (broken user code: retrying burns cluster
  time and can never succeed), TRANSIENT (injected faults, heartbeat
  timeouts, allocation contention: retry with backoff), INFRA (RM/container
  trouble such as preemption or executor-side errors: retry, the cluster may
  recover).
* ``RetryPolicy`` — attempt budget + exponential backoff with an injectable
  sleep so tests run on a fake clock, and fail-fast classes that abort the
  retry loop immediately.

The AM consults the policy between attempts; TaskExecutors produce the
diagnostics; the history server and metrics analyzer surface them.
"""
from __future__ import annotations

import time
import traceback as _tb
from dataclasses import dataclass, field, replace
from enum import Enum
from typing import Callable, Iterable


class FailureClass(Enum):
    FATAL_USER = "FATAL_USER"   # bad user code — never worth retrying
    TRANSIENT = "TRANSIENT"     # flaky env / injected fault — retry w/ backoff
    INFRA = "INFRA"             # RM / container / executor trouble — retry

    def __str__(self) -> str:  # event payloads + summaries read naturally
        return self.value


#: Exception types that indicate the user's program itself is broken; no
#: number of relaunches will fix a module that doesn't import or a name that
#: doesn't resolve.
FATAL_USER_EXCEPTIONS = frozenset({
    "ImportError", "ModuleNotFoundError", "AttributeError", "NameError",
    "SyntaxError", "IndentationError", "NotImplementedError",
})

#: Container exit codes with a known infra meaning (YARN conventions).
EXIT_PREEMPTED = 137        # SIGKILL by the scheduler
EXIT_TEARDOWN = 143         # SIGTERM by the AM (sibling failed / cancel)
EXIT_EXECUTOR_ERROR = 2     # the executor itself (not the child) broke


@dataclass(frozen=True)
class TaskDiagnostics:
    """One task's failure, attributed. ``traceback`` is the full formatted
    traceback when the failure was an exception in the child program."""
    task_id: str
    exit_status: int
    classification: FailureClass
    exception_type: str = ""
    message: str = ""
    traceback: str = ""

    def to_dict(self) -> dict:
        return {
            "task_id": self.task_id,
            "exit_status": self.exit_status,
            "classification": self.classification.value,
            "exception_type": self.exception_type,
            "message": self.message,
            "traceback": self.traceback,
        }

    def describe(self) -> str:
        head = f"{self.task_id}: [{self.classification.value}]"
        if self.exception_type:
            return f"{head} {self.exception_type}: {self.message}"
        return f"{head} exit status {self.exit_status}"


def classify_exception(exc: BaseException | str) -> FailureClass:
    """Map a child-program exception (or its type name) to a failure class."""
    name = exc if isinstance(exc, str) else type(exc).__name__
    if name in FATAL_USER_EXCEPTIONS:
        return FailureClass.FATAL_USER
    return FailureClass.TRANSIENT


def classify_exit(status: int) -> FailureClass:
    """Classify a nonzero exit with no exception attached to it."""
    if status == EXIT_PREEMPTED or status == EXIT_EXECUTOR_ERROR:
        return FailureClass.INFRA
    return FailureClass.TRANSIENT


def diagnose_exception(task_id: str, exc: BaseException,
                       exit_status: int = 1) -> TaskDiagnostics:
    """Build diagnostics from a live exception (captures the traceback)."""
    return TaskDiagnostics(
        task_id=task_id,
        exit_status=exit_status,
        classification=classify_exception(exc),
        exception_type=type(exc).__name__,
        message=str(exc),
        traceback="".join(_tb.format_exception(type(exc), exc,
                                               exc.__traceback__)),
    )


def diagnose_exit(task_id: str, status: int) -> TaskDiagnostics:
    reasons = {
        EXIT_PREEMPTED: "container preempted by the scheduler",
        EXIT_TEARDOWN: "torn down by the AM (a sibling task failed or the "
                       "attempt was cancelled)",
        EXIT_EXECUTOR_ERROR: "task executor error (not the ML program)",
        3: "cancelled before the job rendezvoused",
    }
    return TaskDiagnostics(
        task_id=task_id, exit_status=status,
        classification=classify_exit(status),
        message=reasons.get(status, f"exited with status {status}"))


def diagnose_heartbeat_timeout(task_id: str, timeout_s: float) -> TaskDiagnostics:
    return TaskDiagnostics(
        task_id=task_id, exit_status=-1,
        classification=FailureClass.TRANSIENT,
        exception_type="HeartbeatTimeout",
        message=f"no heartbeat for more than {timeout_s:g}s; "
                "task presumed hung or its node lost")


def diagnose_allocation_failure(error: str) -> TaskDiagnostics:
    # Allocation failures are contention, not broken code: another attempt
    # may find capacity freed (classified TRANSIENT per the survey's
    # fault-tolerance taxonomy).
    return TaskDiagnostics(
        task_id="__allocation__", exit_status=-1,
        classification=FailureClass.TRANSIENT,
        exception_type="AllocationError", message=error)


@dataclass(frozen=True)
class RetryDecision:
    retry: bool
    reason: str
    backoff_s: float = 0.0


@dataclass(frozen=True)
class RetryPolicy:
    """Attempt budget + exponential backoff + fail-fast classes.

    ``sleep`` is injectable so tests drive the backoff on a fake clock; the
    default is the real ``time.sleep``.
    """
    max_attempts: int = 3
    base_backoff_s: float = 0.05
    backoff_multiplier: float = 2.0
    max_backoff_s: float = 2.0
    fail_fast_on: frozenset = frozenset({FailureClass.FATAL_USER})
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False,
                                           compare=False)

    def with_clock(self, sleep: Callable[[float], None]) -> "RetryPolicy":
        return replace(self, sleep=sleep)

    def backoff_for(self, attempt: int) -> float:
        """Backoff before relaunching after ``attempt`` (1-based) failed."""
        raw = self.base_backoff_s * self.backoff_multiplier ** (attempt - 1)
        return min(raw, self.max_backoff_s)

    def decide(self, attempt: int,
               classes: Iterable[FailureClass]) -> RetryDecision:
        classes = set(classes)
        fatal = classes & set(self.fail_fast_on)
        if fatal:
            return RetryDecision(
                False, "fail-fast: " + ", ".join(sorted(c.value for c in fatal))
                + " failures cannot succeed on retry")
        if attempt >= self.max_attempts:
            return RetryDecision(
                False, f"attempt budget exhausted ({self.max_attempts})")
        return RetryDecision(True, "retryable failure classes: "
                             + (", ".join(sorted(c.value for c in classes))
                                or "unknown"),
                             backoff_s=self.backoff_for(attempt))
