"""Azkaban-like workflow manager with a TonY job type (paper §2.1: 'we built
a TonY plugin for one such workflow manager, Azkaban, that lets users add
distributed ML jobs in the same workflow alongside Spark, MapReduce, and
other jobs')."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.client import TonYClient
from repro.core.resources import JobSpec
from repro.core.task_executor import MLProgram


@dataclass
class WorkflowNode:
    name: str
    run: Callable[[dict[str, Any]], Any]       # context -> result
    deps: tuple[str, ...] = ()
    job_type: str = "command"                   # command | tony | spark | ...


@dataclass
class NodeResult:
    name: str
    status: str                                 # SUCCEEDED | FAILED | SKIPPED
    value: Any = None
    error: str | None = None


class Workflow:
    """Topological, dependency-parallel execution of a DAG of nodes."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: dict[str, WorkflowNode] = {}

    def add(self, node: WorkflowNode) -> "Workflow":
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        self.nodes[node.name] = node
        return self

    def add_command(self, name: str, fn: Callable, deps: tuple[str, ...] = ()):
        return self.add(WorkflowNode(name, fn, deps, "command"))

    def add_tony_job(self, name: str, client: TonYClient, job: JobSpec,
                     ml_program: MLProgram, deps: tuple[str, ...] = ()):
        """The TonY plugin: a distributed ML training node in the DAG."""

        def run(ctx: dict[str, Any]):
            result = client.run_and_wait(job, ml_program)
            if not result.succeeded:
                raise RuntimeError(f"tony job {job.name} failed "
                                   f"after {len(result.attempts)} attempts")
            return result

        return self.add(WorkflowNode(name, run, deps, "tony"))

    # ------------------------------------------------------------------
    def _check_dag(self) -> list[str]:
        order, seen, tmp = [], set(), set()

        def visit(n: str):
            if n in seen:
                return
            if n in tmp:
                raise ValueError("workflow DAG has a cycle")
            tmp.add(n)
            for d in self.nodes[n].deps:
                if d not in self.nodes:
                    raise ValueError(f"unknown dependency {d!r} of {n!r}")
                visit(d)
            tmp.discard(n)
            seen.add(n)
            order.append(n)

        for n in sorted(self.nodes):
            visit(n)
        return order

    def execute(self, context: dict[str, Any] | None = None,
                max_parallel: int = 8) -> dict[str, NodeResult]:
        """Run ready nodes in parallel threads; failure skips dependents."""
        self._check_dag()
        context = context if context is not None else {}
        results: dict[str, NodeResult] = {}
        lock = threading.Lock()
        done = threading.Condition(lock)
        running: set[str] = set()

        def ready(n: str) -> bool:
            node = self.nodes[n]
            return all(d in results and results[d].status == "SUCCEEDED"
                       for d in node.deps)

        def blocked_forever(n: str) -> bool:
            return any(d in results and results[d].status != "SUCCEEDED"
                       for d in self.nodes[n].deps)

        def launch(n: str):
            def body():
                node = self.nodes[n]
                try:
                    value = node.run(context)
                    res = NodeResult(n, "SUCCEEDED", value)
                except Exception as e:  # noqa: BLE001
                    res = NodeResult(n, "FAILED", error=f"{type(e).__name__}: {e}")
                with lock:
                    results[n] = res
                    running.discard(n)
                    done.notify_all()

            threading.Thread(target=body, name=f"wf-{n}", daemon=True).start()

        with lock:
            while len(results) < len(self.nodes):
                for n in sorted(self.nodes):
                    if n in results or n in running:
                        continue
                    if blocked_forever(n):
                        results[n] = NodeResult(n, "SKIPPED",
                                                error="dependency failed")
                        continue
                    if ready(n) and len(running) < max_parallel:
                        running.add(n)
                        launch(n)
                if len(results) < len(self.nodes):
                    done.wait(0.05)
        return results
