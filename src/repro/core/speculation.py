"""Speculative execution for straggler tasks (ROADMAP fault-tolerance item).

Synchronous data-parallel training moves at the pace of its slowest member:
one degraded host (thermal throttling, a dying disk, a noisy neighbour)
stretches every step of the whole gang. The classic mitigation — speculative
execution, as in MapReduce/Spark — is to launch a *backup copy* of the slow
task on a different node and let the two race; the first copy to finish wins
and the loser is torn down without prejudice.

This module holds the policy + detection bookkeeping; the AM drives it:

* Executors report per-step progress in their heartbeats (the ML program
  calls ``ctx.step(task_id, attempt, step)`` once per training step).
* The AM feeds the per-task progress map into a ``SpeculationTracker`` on
  every monitor tick. A task whose progress has fallen behind the gang
  *median* by ``slowdown_factor`` for ``patience`` consecutive observations
  is flagged a straggler (``straggler_detected``).
* The AM then asks the RM for one backup container — excluding the
  straggler's node, and respecting the node blacklist like any allocation —
  and launches a speculative ``TaskExecutor`` (``speculative_launched``).
* First copy to finish wins: ``speculative_won`` when the backup beats the
  original, ``speculative_cancelled`` when the original finishes first (or
  the backup itself dies). The loser is torn down with
  ``EXIT_SPECULATION_LOST`` — classified TRANSIENT and *never* charged to
  its node, so speculation can never poison the blacklist.

Speculative executors are addressed as ``<task_id>#<copy>`` (e.g.
``worker:1#1``): the copy suffix keeps their heartbeats, exits, logs, and
chaos hooks distinct from the original's. A chaos ``FaultSpec`` with an
exact task pattern (``worker:1``) therefore does NOT hit the backup — which
is what makes "the backup escapes the slow node" testable — while a
type-wide pattern (``worker:*``) hits both copies.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Any

#: Separator between a task id and its speculative-copy index.
SPEC_COPY_SEP = "#"


def speculative_id(task_id: str, copy: int = 1) -> str:
    """Executor id of the ``copy``-th speculative copy of ``task_id``."""
    return f"{task_id}{SPEC_COPY_SEP}{copy}"


def primary_id(exec_id: str) -> str:
    """Strip the copy suffix: ``worker:1#1`` -> ``worker:1``."""
    return exec_id.split(SPEC_COPY_SEP, 1)[0]


def is_speculative_id(exec_id: str) -> bool:
    return SPEC_COPY_SEP in exec_id


@dataclass(frozen=True)
class SpeculationPolicy:
    """When to consider a task a straggler, and how much to speculate.

    A task is *lagging* when ``progress * slowdown_factor < gang_median``.
    It becomes a straggler after ``patience`` consecutive lagging
    observations (one observation per AM monitor tick, i.e. roughly per
    heartbeat), and only once the gang median has reached ``min_progress``
    — early steps are noisy (compile time, data warmup) and should never
    trigger a backup. ``max_copies_per_attempt`` bounds the total number of
    speculative launches in one attempt so a sick job cannot double its own
    footprint.
    """
    enabled: bool = False
    slowdown_factor: float = 2.0
    patience: int = 5
    min_progress: int = 4
    max_copies_per_attempt: int = 2


class SpeculationTracker:
    """Per-attempt straggler bookkeeping (the AM owns one per attempt).

    Not thread-safe by itself: the AM calls ``observe`` from its single
    monitor loop with a snapshot of the progress map.
    """

    def __init__(self, policy: SpeculationPolicy):
        self.policy = policy
        self.launched = 0
        self.last_median: float = 0.0
        self._lag: dict[str, int] = {}
        self._flagged: set[str] = set()

    def lag_count(self, task_id: str) -> int:
        return self._lag.get(task_id, 0)

    def observe(self, progress: dict[str, int]) -> list[str]:
        """Feed one snapshot of per-task progress (primaries only); returns
        the tasks that just crossed the straggler threshold. Each task is
        flagged at most once per attempt — the AM launches (or fails to
        launch) one backup and the race resolves from there."""
        pol = self.policy
        if not pol.enabled or len(progress) < 2:
            return []
        self.last_median = statistics.median(progress.values())
        if self.last_median < pol.min_progress:
            return []
        out: list[str] = []
        for task_id, step in progress.items():
            if task_id in self._flagged:
                continue
            if step * pol.slowdown_factor < self.last_median:
                n = self._lag.get(task_id, 0) + 1
                self._lag[task_id] = n
                if n >= pol.patience and self.launched < pol.max_copies_per_attempt:
                    self._flagged.add(task_id)
                    out.append(task_id)
            else:
                # caught back up: straggling must be *consecutive*
                self._lag.pop(task_id, None)
        return out

    def note_launched(self) -> None:
        self.launched += 1


@dataclass
class SpeculativeCopy:
    """One live backup: the AM's record of a speculation race in flight.

    ``outcome`` is ``""`` while the race is undecided, then one of
    ``won`` (backup finished first), ``cancelled`` (original finished first,
    or the attempt was torn down), or ``failed`` (the backup itself died
    while the original kept running).
    """
    task_id: str                  # the original (primary) task
    exec_id: str                  # e.g. worker:1#1
    executor: Any                 # the speculative TaskExecutor
    container: Any                # its RM container
    outcome: str = ""
