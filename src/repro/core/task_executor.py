"""TaskExecutor — runs inside each allocated container.

Lifecycle (paper §2.2, step-for-step):
  1. allocate a port, register (host, port) with the AM
  2. wait for the AM's global cluster spec broadcast
  3. materialize the spec + task config as environment variables
  4. spawn the ML program as a child "process" (a callable in a thread)
  5. heartbeat to the AM while the child runs; the first worker also
     registers a visualization UI port (TensorBoard analogue)
  6. register the final exit status with the AM and terminate
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.chaos import NO_CHAOS, FaultInjector
from repro.core.cluster_spec import TaskAddress, task_env
from repro.core.events import EventLog
from repro.core.failures import (
    EXIT_EXECUTOR_ERROR,
    EXIT_SPECULATION_LOST,
    FailureClass,
    TaskDiagnostics,
    diagnose_exception,
)
from repro.core.resources import Container, PortAllocator
from repro.core.speculation import speculative_id

# MLProgram: (env, job_context) -> exit code
MLProgram = Callable[[dict[str, str], "JobContext"], int]


class CancellableBarrier:
    """Reusable barrier that unblocks (returning False) on cancel/timeout
    instead of breaking permanently like threading.Barrier."""

    def __init__(self, n: int):
        self.n = n
        self._count = 0
        self._generation = 0
        self._cond = threading.Condition()

    def wait(self, cancel: threading.Event | None = None,
             timeout: float = 300.0) -> bool:
        deadline = time.monotonic() + timeout
        with self._cond:
            gen = self._generation
            self._count += 1
            if self._count == self.n:
                self._count = 0
                self._generation += 1
                self._cond.notify_all()
                return True
            while self._generation == gen:
                if (cancel is not None and cancel.is_set()) or \
                        time.monotonic() > deadline:
                    self._count -= 1
                    return False
                self._cond.wait(0.05)
            return True

    def reduce(self, n: int = 1) -> None:
        """Shrink the party by ``n`` (elastic gang resize: a shed member will
        never arrive). Releases current waiters if they now form a full
        party."""
        with self._cond:
            self.n = max(1, self.n - n)
            if self._count >= self.n:
                self._count = 0
                self._generation += 1
                self._cond.notify_all()


@dataclass
class JobContext:
    """In-process stand-in for the ML framework's own distributed transport.

    TonY is framework-agnostic: after launch, tasks coordinate via the
    framework's protocol (RPC/MPI/...). In this single-process simulation the
    context carries a barrier + shared dict so all task childs of one job
    attempt can rendezvous — mirroring the launch-time contract without
    reimplementing NCCL.
    """
    world_size: int
    barrier: CancellableBarrier = None  # type: ignore[assignment]
    shared: dict[str, Any] = field(default_factory=dict)
    cancel: threading.Event = field(default_factory=threading.Event)
    workdir: str = ""
    # fault-injection hooks for the ML program (``ctx.chaos.check_step``);
    # NO_CHAOS by default so programs can call it unconditionally
    chaos: FaultInjector = None  # type: ignore[assignment]
    # per-executor step progress (exec_id -> latest step), written by the ML
    # program via ``report_progress``/``step`` and read by the executor's
    # heartbeat loop — the AM's straggler detection feeds off it
    progress: dict[str, int] = field(default_factory=dict)
    # event log for ML-program-side telemetry (e.g. ckpt_committed); None in
    # bare unit contexts
    events: EventLog | None = None
    # flush callbacks for in-flight async work (checkpoint writer,
    # prefetcher): registered by the ML program, drained by graceful
    # teardown paths so no committed-but-unpublished work is lost
    _flushers: list[Callable[[], None]] = field(default_factory=list)

    def __post_init__(self):
        if self.barrier is None:
            self.barrier = CancellableBarrier(self.world_size)
        if self.chaos is None:
            self.chaos = NO_CHAOS

    def rendezvous(self, timeout: float = 300.0,
                   exec_id: str | None = None, attempt: int = 0) -> bool:
        """Gang barrier. When the caller identifies itself (``exec_id``),
        an open chaos PARTITION window blocks it *before* it joins the
        barrier — a partitioned task can't reach its peers — until the
        window closes, cancel fires, or the timeout burns down."""
        deadline = time.monotonic() + timeout
        while self.chaos.partition_active(exec_id, attempt):
            if self.cancel.is_set() or time.monotonic() > deadline:
                return False
            time.sleep(0.01)
        remaining = max(0.0, deadline - time.monotonic())
        return self.barrier.wait(self.cancel, remaining)

    def shrink_world(self, n: int = 1) -> None:
        """Elastic resize mid-attempt: an INFRA-lost member above the floor
        was shed, so future barriers expect one fewer participant. Pending
        async work is flushed first — a resize must not strand a checkpoint
        that already finished staging."""
        self.flush_async()
        self.world_size = max(1, self.world_size - n)
        self.shared["world_size"] = self.world_size
        self.barrier.reduce(n)

    def register_flusher(self, fn: Callable[[], None]) -> None:
        """Register a flush hook for in-flight async work (async checkpoint
        writer, prefetch loader). Graceful teardown paths call
        ``flush_async`` so committed work is published before exit."""
        self._flushers.append(fn)

    def flush_async(self) -> None:
        """Drain registered flushers. Never raises: a flusher's deferred
        error belongs to the thread that owns it (the ML program re-raises
        it from its own save/flush), not to teardown."""
        for fn in list(self._flushers):
            try:
                fn()
            except Exception:  # noqa: BLE001 - teardown must proceed
                pass

    def report_progress(self, exec_id: str, step: int) -> None:
        self.progress[exec_id] = step

    def step(self, exec_id: str, attempt: int, step: int) -> None:
        """One training step's orchestrator side: record progress (carried to
        the AM by the next executor heartbeat, driving straggler detection)
        and consult the chaos plan (which may delay the step — SLOW_STEP —
        or raise a planned fault)."""
        self.progress[exec_id] = step
        self.chaos.check_step(exec_id, attempt, step)


class TaskExecutor:
    HEARTBEAT_INTERVAL_S = 0.02

    def __init__(self, task_type: str, index: int, container: Container,
                 am: "ApplicationMasterProtocol", ml_program: MLProgram,
                 job_args: dict[str, str], ctx: JobContext,
                 ports: PortAllocator, events: EventLog,
                 is_chief_worker: bool = False,
                 chaos: FaultInjector | None = None,
                 speculative: bool = False):
        self.task_type = task_type
        self.index = index
        self.container = container
        self.am = am
        self.ml_program = ml_program
        self.job_args = job_args
        self.ctx = ctx
        self.ports = ports
        self.events = events
        self.is_chief_worker = is_chief_worker
        self.chaos = chaos or ctx.chaos or NO_CHAOS
        self.task_id = f"{task_type}:{index}"
        # a speculative backup copy runs the same (task_type, index) under a
        # copy-suffixed id so its heartbeats/exits/logs/chaos hooks stay
        # distinct from the original's; it skips registration (the gang's
        # cluster spec is already built) — the AM pre-delivers the spec
        self.speculative = speculative
        self.exec_id = speculative_id(self.task_id) if speculative else self.task_id
        # per-executor teardown, distinct from ctx.cancel (whole-gang): the
        # AM sets this to kill one copy after a speculation race resolves
        self.cancel = threading.Event()
        self.exit_status: int | None = None
        self.diagnostics: TaskDiagnostics | None = None
        self.log_lines: list[str] = []
        self.metrics: dict[str, float] = {}
        self._cluster_spec_ready = threading.Event()
        self._cluster_spec: dict | None = None
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def start(self) -> None:
        self._thread = threading.Thread(target=self._run, name=f"executor-{self.exec_id}",
                                        daemon=True)
        self._thread.start()

    def join(self, timeout: float | None = None) -> None:
        if self._thread:
            self._thread.join(timeout)

    def deliver_cluster_spec(self, spec: dict) -> None:
        self._cluster_spec = spec
        self._cluster_spec_ready.set()

    def log(self, line: str) -> None:
        self.log_lines.append(line)

    # ------------------------------------------------------------------
    def _run(self) -> None:
        src = f"executor:{self.exec_id}"
        try:
            # 1. port allocation + registration (speculative copies skip
            # registration: the gang already rendezvoused and the cluster
            # spec is pre-delivered by the AM before start())
            port = self.ports.allocate()
            addr = TaskAddress(self.task_type, self.index,
                               self.container.node_id, port)
            ui_port = None
            if not self.speculative:
                if self.is_chief_worker:
                    ui_port = self.ports.allocate()  # TensorBoard analogue
                self.events.emit(src, "task_registering", endpoint=addr.endpoint)
                self.am.register_task(self, addr, ui_port=ui_port)

            # 2. wait for the global cluster spec
            if not self._cluster_spec_ready.wait(timeout=60.0):
                raise TimeoutError("cluster spec broadcast never arrived")

            # 3. env materialization
            env = task_env(self._cluster_spec, self.task_type, self.index,
                           self.job_args)
            env["CONTAINER_ID"] = self.container.container_id
            env["UI_PORT"] = str(ui_port) if ui_port else ""
            if self.speculative:
                env["SPECULATIVE"] = "1"
            self.events.emit(src, "task_env_ready", world=env["WORLD_SIZE"])

            # 4. spawn the child + 5. heartbeat until done
            result: dict[str, Any] = {}

            def child():
                try:
                    result["exit"] = int(self.ml_program(env, self.ctx) or 0)
                except Exception as e:  # noqa: BLE001 - child crash is data
                    self.log(f"child crashed: {type(e).__name__}: {e}")
                    self.log(traceback.format_exc())
                    result["exit"] = 1
                    # capture the failure for the AM: type, message and the
                    # full formatted traceback, pre-classified
                    diag = diagnose_exception(self.exec_id, e)
                    result["diag"] = diag
                    self.ctx.shared[f"diag:{self.exec_id}"] = diag.to_dict()

            child_t = threading.Thread(target=child, name=f"ml-{self.exec_id}",
                                       daemon=True)
            child_t.start()
            attempt = int(self.ctx.shared.get("attempt", 1))
            self.chaos.task_started(self.exec_id, attempt)
            while child_t.is_alive():
                if self.chaos.drop_heartbeat(self.exec_id, attempt) or \
                        self.chaos.partition_active(self.exec_id, attempt):
                    # chaos: simulated network partition — the AM sees a
                    # silent task and attributes a heartbeat timeout
                    pass
                else:
                    # heartbeats carry the child's latest step so the AM can
                    # spot stragglers (core/speculation.py)
                    self.am.heartbeat(self.exec_id,
                                      progress=self.ctx.progress.get(self.exec_id))
                if self.ctx.cancel.is_set():
                    # AM-initiated teardown: abandon the child (thread stand-in
                    # for SIGKILL on the real container process)
                    self.log("teardown requested; abandoning child")
                    result.setdefault("exit", 143)
                    break
                if self.cancel.is_set():
                    # this copy lost its speculation race — benign teardown,
                    # classified TRANSIENT and never charged to the node
                    self.log("lost the speculation race; torn down")
                    result.setdefault("exit", EXIT_SPECULATION_LOST)
                    break
                if self.container.state.value == "preempted" or \
                        self.chaos.should_preempt(self.exec_id, attempt):
                    # the scheduler reclaimed this container (capacity-
                    # scheduler preemption, organic or chaos-injected);
                    # report SIGKILL-style exit so the AM relaunches via the
                    # normal fault-tolerance path
                    self.log("container preempted by scheduler")
                    result.setdefault("exit", 137)
                    break
                child_t.join(self.HEARTBEAT_INTERVAL_S)

            # graceful teardown: let in-flight async work (checkpoint
            # writer, prefetcher) finish committing before the exit is
            # reported — an already-staged checkpoint must still publish
            # its ckpt_step so the next attempt resumes from it
            self.ctx.flush_async()
            self.exit_status = int(result.get("exit", 0))
            self.diagnostics = result.get("diag")
            self.metrics = dict(self.ctx.shared.get(f"metrics:{self.exec_id}", {}))
        except Exception as e:  # noqa: BLE001
            self.log(f"executor error: {e}")
            self.exit_status = EXIT_EXECUTOR_ERROR
            self.diagnostics = TaskDiagnostics(
                task_id=self.exec_id, exit_status=EXIT_EXECUTOR_ERROR,
                classification=FailureClass.INFRA,
                exception_type=type(e).__name__, message=str(e),
                traceback=traceback.format_exc())
        finally:
            self.events.emit(src, "task_finished", exit=self.exit_status)
            self.am.report_exit(self.exec_id, self.exit_status or 0,
                                diagnostics=self.diagnostics)


class ApplicationMasterProtocol:
    """Interface TaskExecutors call back into (implemented by the AM)."""

    def register_task(self, executor: TaskExecutor, addr: TaskAddress,
                      ui_port: int | None = None) -> None:
        raise NotImplementedError

    def heartbeat(self, task_id: str, progress: int | None = None) -> None:
        raise NotImplementedError

    def report_exit(self, task_id: str, status: int,
                    diagnostics: TaskDiagnostics | None = None) -> None:
        raise NotImplementedError


def _now() -> float:
    return time.monotonic()
