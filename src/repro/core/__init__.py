"""TonY orchestrator core — the paper's contribution.

Client -> (archive) -> scheduler backend -> ApplicationMaster -> containers
-> TaskExecutors -> cluster spec -> ML child processes -> heartbeats ->
exit statuses, with relaunch-on-failure and history/metrics collection.
"""
from repro.core.appmaster import ApplicationMaster, AttemptReport, JobResult  # noqa: F401
from repro.core.client import (  # noqa: F401
    JobHandle,
    TonYClient,
    YarnLikeBackend,
    format_failure_report,
)
from repro.core.cluster_spec import build_cluster_spec, task_env  # noqa: F401
from repro.core.config import job_spec_from_props, parse_tony_xml, to_tony_xml  # noqa: F401
from repro.core.events import FAILURE_EVENT_KINDS, Event, EventLog  # noqa: F401
from repro.core.failures import (  # noqa: F401
    FailureClass,
    RetryDecision,
    RetryPolicy,
    TaskDiagnostics,
    classify_exception,
    classify_exit,
)
from repro.core.history import JobHistoryServer, MetricsAnalyzer  # noqa: F401
from repro.core.resources import (  # noqa: F401
    Container,
    ContainerRequest,
    JobSpec,
    Node,
    Resource,
    TaskSpec,
)
from repro.core.rm import AllocationError, ResourceManager, make_cluster  # noqa: F401
from repro.core.task_executor import JobContext, TaskExecutor  # noqa: F401
from repro.core.workflow import Workflow, WorkflowNode  # noqa: F401
