"""TonY orchestrator core — the paper's contribution.

Client -> (archive) -> scheduler backend -> ApplicationMaster -> containers
-> TaskExecutors -> cluster spec -> ML child processes -> heartbeats ->
exit statuses, with relaunch-on-failure and history/metrics collection.
"""
from repro.core.appmaster import ApplicationMaster, AttemptReport, JobResult  # noqa: F401
from repro.core.chaos import (  # noqa: F401
    NO_CHAOS,
    ChaosKill,
    ChaosOOM,
    ChaosPartition,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
)
from repro.core.client import (  # noqa: F401
    JobHandle,
    TonYClient,
    YarnLikeBackend,
    format_failure_report,
)
from repro.core.cluster_spec import (  # noqa: F401
    build_cluster_spec,
    spec_task_counts,
    spec_world_size,
    task_env,
)
from repro.core.config import job_spec_from_props, parse_tony_xml, to_tony_xml  # noqa: F401
from repro.core.events import (  # noqa: F401
    ELASTIC_EVENT_KINDS,
    FAILURE_EVENT_KINDS,
    RECOVERY_EVENT_KINDS,
    SPECULATION_EVENT_KINDS,
    Event,
    EventLog,
)
from repro.core.failures import (  # noqa: F401
    EXIT_SPECULATION_LOST,
    FailureClass,
    RetryDecision,
    RetryPolicy,
    TaskDiagnostics,
    classify_exception,
    classify_exit,
    is_oom_signature,
)
from repro.core.history import JobHistoryServer, MetricsAnalyzer  # noqa: F401
from repro.core.resources import (  # noqa: F401
    Container,
    ContainerRequest,
    JobSpec,
    Node,
    Resource,
    TaskSpec,
)
from repro.core.rm import (  # noqa: F401
    AllocationError,
    NodeHealthTracker,
    ResourceManager,
    make_cluster,
)
from repro.core.speculation import (  # noqa: F401
    SpeculationPolicy,
    SpeculationTracker,
    is_speculative_id,
    primary_id,
    speculative_id,
)
from repro.core.task_executor import JobContext, TaskExecutor  # noqa: F401
from repro.core.workflow import Workflow, WorkflowNode  # noqa: F401
