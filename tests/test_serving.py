"""Serving-path tests: batched generation across architecture families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import batched_generate
from repro.models import model as M


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-3b", "recurrentgemma-2b"])
def test_batched_generate_shapes_and_determinism(arch, rng):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, rng)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, size=(3, 5))
    g1, s1 = batched_generate(cfg, params, prompts, gen_len=7, cache_len=12)
    g2, _ = batched_generate(cfg, params, prompts, gen_len=7, cache_len=12)
    assert g1.shape == (3, 7)
    assert (g1 == g2).all()              # greedy decode is deterministic
    assert s1["tokens_generated"] == 21
    assert (g1 >= 0).all() and (g1 < cfg.vocab_size).all()


def test_generate_uses_prompt_context(rng):
    """Different prompts must lead to different continuations (cache works)."""
    cfg = get_smoke_config("qwen3-1.7b")
    params = M.init_params(cfg, rng)
    r = np.random.default_rng(1)
    p1 = r.integers(0, cfg.vocab_size, size=(1, 6))
    p2 = (p1 + 13) % cfg.vocab_size
    g1, _ = batched_generate(cfg, params, p1, gen_len=6, cache_len=12)
    g2, _ = batched_generate(cfg, params, p2, gen_len=6, cache_len=12)
    assert (g1 != g2).any()


def test_decode_state_pos_advances(rng):
    cfg = get_smoke_config("llama3.2-3b")
    params = M.init_params(cfg, rng)
    state = M.init_decode_state(cfg, params, 2, 8)
    assert int(state["pos"]) == 0
    tok = jnp.zeros((2, 1), jnp.int32)
    _, state = M.decode_step(cfg, params, state, tok, 8)
    _, state = M.decode_step(cfg, params, state, tok, 8)
    assert int(state["pos"]) == 2
