"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,KV,hd,causal,window", [
    (1, 128, 4, 4, 64, True, 0),      # MHA causal
    (2, 256, 4, 2, 64, True, 0),      # GQA
    (1, 128, 8, 1, 32, True, 0),      # MQA
    (1, 256, 4, 4, 64, True, 64),     # sliding window
    (2, 128, 2, 2, 128, False, 0),    # bidirectional (encoder)
    (1, 512, 2, 1, 64, True, 128),    # long + window + MQA
])
def test_flash_attention_sweep(B, T, H, KV, hd, causal, window, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == want.shape
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - want.astype(jnp.float32))) < _tol(dtype) * 3


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shape_invariance(block_q, block_k, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True, block_q=block_q,
                              block_k=block_k)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert jnp.max(jnp.abs(out - want)) < 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,C,bt,bc", [
    (1, 128, 128, 128, 128),
    (2, 256, 256, 64, 128),
    (1, 64, 512, 32, 128),
    (3, 96, 64, 32, 64),
])
def test_linear_scan_sweep(B, T, C, bt, bc, dtype, rng):
    ks = jax.random.split(rng, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, C))).astype(dtype)
    b = jax.random.normal(ks[1], (B, T, C), dtype)
    out = ops.linear_scan(a, b, block_t=bt, block_c=bc)
    want = ref.linear_scan_ref(a, b)
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - want.astype(jnp.float32))) < _tol(dtype) * 5


@pytest.mark.parametrize("B,T,H,K,bt", [
    (1, 64, 2, 32, 64),
    (2, 128, 4, 64, 32),
    (1, 96, 3, 32, 32),
])
def test_wkv_sweep(B, T, H, K, bt, rng):
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.3)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    out = ops.wkv(r, k, v, lw, u, block_t=bt)
    want = ref.wkv_ref(r, k, v, lw, u)
    assert jnp.max(jnp.abs(out - want)) < 1e-4


@pytest.mark.parametrize("shape", [(128, 256), (256, 512), (2, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype, rng):
    ks = jax.random.split(rng, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    s = jax.random.normal(ks[1], (shape[-1],), jnp.float32) * 0.1
    out = ops.rmsnorm(x, s)
    want = ref.rmsnorm_ref(x, s)
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - want.astype(jnp.float32))) < _tol(dtype)


def test_flash_attention_grad_matches_ref(rng):
    """The kernel is used in training too: check VJP against the oracle."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))

    g1 = jax.grad(lambda q: ops.flash_attention(q, k, v, causal=True).sum())(q)
    g2 = jax.grad(lambda q: ref.flash_attention_ref(q, k, v, causal=True).sum())(q)
    assert jnp.max(jnp.abs(g1 - g2)) < 1e-4
