"""Unit tests for the dry-run / roofline machinery (pure functions — the
512-device lowering itself is covered by the matrix artifacts)."""
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.dryrun_lib import (
    _extrapolate,
    model_flops,
    parse_collective_bytes,
    rwkv_correction_flops,
    should_skip,
)

HLO = """
ENTRY %main {
  %ag = bf16[16,1024,128]{2,1,0} all-gather(%p0), replica_groups={...}
  %ar.1 = f32[256,4096]{1,0} all-reduce(%x), to_apply=%add
  %rs = (f32[64,64]{1,0}, f32[64,64]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a = bf16[8,128,64]{2,1,0} all-to-all(%y), dimensions={0}
  %cp-start = bf16[2,2]{1,0} collective-permute-start(%z), source_target_pairs={{0,1}}
  %done = bf16[2,2]{1,0} collective-permute-done(%cp-start)
  %not_a_collective = f32[4]{0} add(%c, %d)
}
"""


def test_parse_collective_bytes_kinds_and_sizes():
    out = parse_collective_bytes(HLO)
    assert out["all-gather"] == 16 * 1024 * 128 * 2
    assert out["all-reduce"] == 256 * 4096 * 4
    assert out["reduce-scatter"] == 2 * 64 * 64 * 4   # tuple result summed
    assert out["all-to-all"] == 8 * 128 * 64 * 2
    assert out["collective-permute"] == 2 * 2 * 2     # -start counted, -done not
    assert out["count"] == 5


def test_extrapolation_linear():
    e1 = {"flops": 10.0, "bytes_accessed": 100.0,
          "collectives": {k: 1.0 for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute")} | {"count": 3},
          "memory": None}
    e2 = {"flops": 18.0, "bytes_accessed": 160.0,
          "collectives": {k: 1.5 for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute")} | {"count": 3},
          "memory": None}
    ext = _extrapolate(e1, e2, reps=10.0)
    # fixed = a - marg = 2; total = 2 + 10*8 = 82
    assert ext["flops"] == pytest.approx(82.0)
    assert ext["bytes_accessed"] == pytest.approx(40.0 + 10 * 60.0)
    assert ext["collectives"]["all-gather"] == pytest.approx(0.5 + 10 * 0.5)


def test_extrapolation_negative_marginal_fallback():
    base = {"collectives": {k: 0.0 for k in
                            ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute")} | {"count": 0},
            "memory": None}
    e1 = {**base, "flops": 10.0, "bytes_accessed": 120.0}
    e2 = {**base, "flops": 18.0, "bytes_accessed": 100.0}  # fusion noise
    ext = _extrapolate(e1, e2, reps=8.0)
    assert ext["bytes_accessed"] == pytest.approx(100.0 * 8.0 / 2.0)  # proportional
    assert ext["flops"] == pytest.approx(2.0 + 8 * 8.0)


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen3-1.7b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6.0 * n * 256 * 4096)
    assert de == pytest.approx(2.0 * n * 128)


def test_moe_active_params_much_smaller_than_total():
    cfg = get_config("llama4-maverick-400b-a17b")
    assert cfg.active_param_count() < cfg.param_count() / 5
    dense = get_config("llama3-405b")
    assert dense.active_param_count() == dense.param_count()


def test_rwkv_correction_only_for_ssm():
    assert rwkv_correction_flops(get_config("qwen3-1.7b"),
                                 INPUT_SHAPES["train_4k"]) == 0.0
    c = rwkv_correction_flops(get_config("rwkv6-3b"), INPUT_SHAPES["train_4k"])
    cfg = get_config("rwkv6-3b")
    want = 6.0 * cfg.rwkv_heads * 64 * 64 * 32 * 256 * 4096 * 3
    assert c == pytest.approx(want)


def test_should_skip_matrix():
    assert should_skip(get_config("whisper-base"), INPUT_SHAPES["long_500k"])
    assert should_skip(get_config("whisper-base"), INPUT_SHAPES["decode_32k"]) is None
    for a in ("rwkv6-3b", "recurrentgemma-2b", "llama3-405b"):
        assert should_skip(get_config(a), INPUT_SHAPES["long_500k"]) is None


def test_param_counts_match_public_scale():
    """Sanity: assigned configs land near their nameplate sizes."""
    approx = {
        "llama3-405b": 405e9,
        "deepseek-coder-33b": 33e9,
        "qwen3-1.7b": 2e9,
        "llama3.2-3b": 3.2e9,
        "rwkv6-3b": 3.1e9,
        "recurrentgemma-2b": 2.7e9,
        "llama4-maverick-400b-a17b": 400e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.5 * want < got < 1.6 * want, (arch, got, want)
