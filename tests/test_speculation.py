"""Speculative execution for straggler tasks (core/speculation.py + AM).

Everything runs against a *seeded* FaultPlan (CHAOS_SEED, overridable in CI):
the SLOW_STEP fault makes one worker a deterministic straggler, the AM's
detection flags it off heartbeat progress, and the backup race resolves the
same way every run.
"""
import os
import time

import pytest

from repro.core import (
    EXIT_SPECULATION_LOST,
    EventLog,
    FailureClass,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    JobHistoryServer,
    MetricsAnalyzer,
    SpeculationPolicy,
    SpeculationTracker,
    TonYClient,
    YarnLikeBackend,
    classify_exit,
    is_speculative_id,
    job_spec_from_props,
    make_cluster,
    primary_id,
    speculative_id,
)
from repro.core.failures import diagnose_exit

pytestmark = pytest.mark.chaos

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))

SPEC_EVENTS = ("straggler_detected", "speculative_launched",
               "speculative_won", "speculative_cancelled")


def _job(workers=3, attempts=3):
    return job_spec_from_props({
        "tony.application.name": "speculation",
        "tony.application.max-attempts": str(attempts),
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })


def make_gang_program(steps, work_s=0.01):
    """Every worker steps in lockstep-ish; a speculative copy joins the
    already-formed gang (skips rendezvous) under its #1 exec id."""

    def program(env, ctx):
        tid = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        speculative = env.get("SPECULATIVE") == "1"
        exec_id = tid + "#1" if speculative else tid
        attempt = int(ctx.shared.get("attempt", 1))
        if not speculative and not ctx.rendezvous(timeout=10):
            return 3
        for step in range(steps):
            if ctx.cancel.is_set():
                return 143
            ctx.step(exec_id, attempt, step)
            time.sleep(work_s)
        return 0

    return program


def _chaos_cluster(plan, **kw):
    ev = EventLog()
    rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev), **kw)
    return rm, ev


# ----------------------------------------------------------------------
# Unit: exec-id convention + loser classification


def test_speculative_id_roundtrip():
    assert speculative_id("worker:1") == "worker:1#1"
    assert speculative_id("worker:1", copy=2) == "worker:1#2"
    assert primary_id("worker:1#1") == "worker:1"
    assert primary_id("worker:1") == "worker:1"
    assert is_speculative_id("worker:1#1")
    assert not is_speculative_id("worker:1")


def test_speculation_lost_exit_is_transient_and_explained():
    # the loser's teardown must never look like an infra problem — that is
    # what keeps races from striking nodes into the blacklist
    assert classify_exit(EXIT_SPECULATION_LOST) is FailureClass.TRANSIENT
    d = diagnose_exit("worker:1", EXIT_SPECULATION_LOST)
    assert "speculat" in d.message and d.classification is FailureClass.TRANSIENT


# ----------------------------------------------------------------------
# Unit: SpeculationTracker detection rule


def test_tracker_flags_after_patience_consecutive_lags():
    tr = SpeculationTracker(SpeculationPolicy(
        enabled=True, slowdown_factor=2.0, patience=3, min_progress=4))
    # median below min_progress: detection not armed yet
    assert tr.observe({"worker:0": 2, "worker:1": 1, "worker:2": 2}) == []
    assert tr.lag_count("worker:1") == 0
    # lagging (1*2 < median 8) but patience not yet reached
    assert tr.observe({"worker:0": 8, "worker:1": 1, "worker:2": 8}) == []
    assert tr.observe({"worker:0": 9, "worker:1": 1, "worker:2": 9}) == []
    assert tr.lag_count("worker:1") == 2
    flagged = tr.observe({"worker:0": 10, "worker:1": 2, "worker:2": 10})
    assert flagged == ["worker:1"]
    assert tr.last_median == 10
    # flagged at most once per attempt
    assert tr.observe({"worker:0": 11, "worker:1": 2, "worker:2": 11}) == []


def test_tracker_lag_must_be_consecutive_and_needs_a_gang():
    tr = SpeculationTracker(SpeculationPolicy(
        enabled=True, slowdown_factor=2.0, patience=2, min_progress=1))
    assert tr.observe({"worker:0": 10}) == []           # no gang, no median
    assert tr.observe({"worker:0": 10, "worker:1": 1}) == []
    tr.observe({"worker:0": 10, "worker:1": 10})        # caught up: reset
    assert tr.lag_count("worker:1") == 0
    assert tr.observe({"worker:0": 12, "worker:1": 1}) == []
    assert tr.observe({"worker:0": 13, "worker:1": 1}) == ["worker:1"]


def test_tracker_respects_copy_budget_and_disabled_policy():
    assert SpeculationTracker(SpeculationPolicy(enabled=False)).observe(
        {"a": 100, "b": 1}) == []
    tr = SpeculationTracker(SpeculationPolicy(
        enabled=True, patience=1, min_progress=1, max_copies_per_attempt=1))
    assert tr.observe({"a": 10, "b": 10, "c": 1}) == ["c"]
    tr.note_launched()
    # budget spent: a second straggler is not flagged
    assert tr.observe({"a": 20, "b": 1, "c": 1}) == []


# ----------------------------------------------------------------------
# Unit: SLOW_STEP chaos fault (fake sleep — no wall-clock in the unit)


def test_slow_step_delays_only_the_window_and_matching_task():
    slept = []
    inj = FaultInjector(
        FaultPlan(seed=CHAOS_SEED).add(
            FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=2,
                      until_step=4, delay_s=0.25)),
        events=(ev := EventLog()), sleep=slept.append)
    for step in range(7):
        inj.check_step("worker:1", 1, step)
    inj.check_step("worker:0", 1, 3)          # different task: untouched
    inj.check_step("worker:1#1", 1, 3)        # exact pattern misses the copy
    assert slept == [0.25, 0.25, 0.25]        # steps 2, 3, 4 only
    # one chaos_injected per (task, attempt) entering the window
    assert ev.count("chaos_injected") == 1
    p = ev.of_kind("chaos_injected")[0].payload
    assert p["fault"] == "slow_step" and p["delay_s"] == 0.25


def test_slow_step_wildcard_hits_speculative_copies_too():
    slept = []
    inj = FaultInjector(
        FaultPlan(seed=CHAOS_SEED).add(
            FaultSpec(FaultKind.SLOW_STEP, task="worker:*", delay_s=0.1)),
        sleep=slept.append)
    inj.check_step("worker:1", 1, 0)
    inj.check_step("worker:1#1", 1, 0)
    inj.check_step("ps:0", 1, 0)
    assert slept == [0.1, 0.1]


# ----------------------------------------------------------------------
# Unit: RM allocation exclusion (keeps the backup off the straggler's node)


def test_allocate_exclude_nodes():
    from repro.core import AllocationError, ContainerRequest, Resource
    rm = make_cluster(num_gpu_nodes=2, num_cpu_nodes=0)
    app = rm.submit_application("x", "default")
    req = ContainerRequest(Resource(1024, 1, 1), "gpu")
    c = rm.allocate(app, req, exclude_nodes={"gpu-node-0"})
    assert c.node_id == "gpu-node-1"
    with pytest.raises(AllocationError, match="excluding"):
        rm.allocate(app, req, exclude_nodes={"gpu-node-0", "gpu-node-1"})
    rm.release(c.container_id)
    assert rm.invariants_ok()


# ----------------------------------------------------------------------
# Tentpole e2e: injected straggler -> detection -> backup wins


def test_backup_wins_race_and_straggler_node_is_never_struck():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=2,
                  delay_s=0.08))
    rm, ev = _chaos_cluster(plan)
    pol = SpeculationPolicy(enabled=True, slowdown_factor=2.0, patience=3,
                            min_progress=4)
    job = _job()
    res = TonYClient(YarnLikeBackend(rm, speculation=pol)).run_and_wait(
        job, make_gang_program(12), timeout=60)

    assert res.succeeded and len(res.attempts) == 1
    a = res.attempts[0]
    assert a.stragglers == ["worker:1"]
    assert a.speculation == {"worker:1": "won"}
    assert res.speculation == {"a1/worker:1": "won"}
    # the original was torn down as the loser, not as a failure
    assert a.exit_statuses["worker:1"] == EXIT_SPECULATION_LOST
    assert a.exit_statuses["worker:1#1"] == 0
    assert a.failed_tasks == [] and res.diagnostics == {}
    # the backup ran on a different node than the straggler
    assert a.nodes["worker:1#1"] != a.nodes["worker:1"]
    # losing a race never charges the slow (but alive) node
    assert rm.health.snapshot()["failures"] == {}
    assert res.blacklisted_nodes == []
    # the full event trail, once each, and on the failure timeline
    counts = {k: ev.count(k) for k in SPEC_EVENTS}
    assert counts == {"straggler_detected": 1, "speculative_launched": 1,
                      "speculative_won": 1, "speculative_cancelled": 0}
    launched = ev.of_kind("speculative_launched")[0].payload
    assert launched["exec_id"] == "worker:1#1"
    assert launched["avoided_node"] == a.nodes["worker:1"]
    timeline = {e.kind for e in ev.failure_timeline()}
    assert {"straggler_detected", "speculative_won"} <= timeline
    # the loser's copy log exists under its exec id
    assert "a1/worker:1#1" in res.task_logs
    assert not rm.live_containers() and rm.invariants_ok()

    # history + analyzer surface the race
    hist = JobHistoryServer()
    hist.record(job, res)
    s = hist.summary(res.app_id)
    assert s["stragglers"] == ["worker:1"]
    assert s["speculation"] == {"a1/worker:1": "won"}
    sugg = [g for g in MetricsAnalyzer().analyze(job, res)
            if g.kind == "straggler"]
    assert len(sugg) == 1 and a.nodes["worker:1"] in sugg[0].message


def test_original_wins_race_and_backup_is_cancelled_cleanly():
    # the original is slow only for steps 1-3 then recovers; the backup is
    # slowed its whole life (exact copy-id pattern) -> the original wins
    plan = (FaultPlan(seed=CHAOS_SEED)
            .add(FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=1,
                           until_step=3, delay_s=0.08))
            .add(FaultSpec(FaultKind.SLOW_STEP, task="worker:1#1",
                           delay_s=0.05)))
    rm, ev = _chaos_cluster(plan)
    pol = SpeculationPolicy(enabled=True, slowdown_factor=2.0, patience=2,
                            min_progress=3)
    res = TonYClient(YarnLikeBackend(rm, speculation=pol)).run_and_wait(
        _job(), make_gang_program(10, work_s=0.02), timeout=60)

    assert res.succeeded and len(res.attempts) == 1
    a = res.attempts[0]
    assert a.speculation == {"worker:1": "cancelled"}
    assert a.exit_statuses["worker:1"] == 0
    assert a.exit_statuses["worker:1#1"] == EXIT_SPECULATION_LOST
    assert a.failed_tasks == [] and res.diagnostics == {}
    assert ev.count("speculative_won") == 0
    cancelled = ev.of_kind("speculative_cancelled")
    assert len(cancelled) == 1
    assert cancelled[0].payload["reason"] == "original finished first"
    assert rm.health.snapshot()["failures"] == {}
    assert not rm.live_containers() and rm.invariants_ok()


def test_speculation_denied_when_no_other_node_fits():
    # single GPU node: the backup has nowhere to go (the straggler's own
    # node is excluded) — the AM degrades gracefully and the job still
    # finishes, just at straggler pace
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=2,
                  delay_s=0.04))
    rm, ev = _chaos_cluster(plan, num_gpu_nodes=1, num_cpu_nodes=0)
    pol = SpeculationPolicy(enabled=True, slowdown_factor=2.0, patience=3,
                            min_progress=4)
    res = TonYClient(YarnLikeBackend(rm, speculation=pol)).run_and_wait(
        _job(), make_gang_program(12), timeout=60)
    assert res.succeeded and len(res.attempts) == 1
    assert res.attempts[0].stragglers == ["worker:1"]
    assert res.attempts[0].speculation == {}          # nothing launched
    assert ev.count("straggler_detected") == 1
    assert ev.count("speculative_launched") == 0
    cancelled = ev.of_kind("speculative_cancelled")
    assert len(cancelled) == 1
    assert "backup allocation failed" in cancelled[0].payload["reason"]
    assert not rm.live_containers() and rm.invariants_ok()


def test_speculation_disabled_by_default_no_detection():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=2,
                  delay_s=0.03))
    rm, ev = _chaos_cluster(plan)
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        _job(), make_gang_program(10), timeout=60)
    assert res.succeeded
    assert all(ev.count(k) == 0 for k in SPEC_EVENTS)
    assert res.attempts[0].speculation == {} and res.speculation == {}
