"""Model correctness: decode-vs-forward equivalence per family, masking,
rope, recurrent state carry."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as A
from repro.models import model as M
from repro.models import recurrent as R

DECODE_ARCHS = ["qwen3-1.7b", "llama3.2-3b", "recurrentgemma-2b", "rwkv6-3b",
                "whisper-base", "llama-3.2-vision-90b", "deepseek-coder-33b"]


def _ctx_for(cfg, params, batch):
    if cfg.is_encoder_decoder:
        return M.encode(cfg, params, batch["frames"])
    if cfg.uses_media:
        return batch["media"].astype(jnp.dtype(cfg.dtype))
    return None


def _decode_all(cfg, params, tokens, cache_len, ctx):
    state = M.init_decode_state(cfg, params, tokens.shape[0], cache_len,
                                context=ctx)
    outs = []
    for t in range(tokens.shape[1]):
        lg, state = M.decode_step(cfg, params, state, tokens[:, t:t + 1],
                                  cache_len)
        outs.append(lg)
    return jnp.concatenate(outs, axis=1), state


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch, rng):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, rng)
    B, T = 2, 12
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(rng, (B, cfg.num_media_tokens, cfg.d_model))
    elif cfg.uses_media:
        batch["media"] = jax.random.normal(rng, (B, cfg.num_media_tokens, cfg.d_model))
    full, _ = M.forward(cfg, params, batch)
    dec, _ = _decode_all(cfg, params, tokens, T, _ctx_for(cfg, params, batch))
    assert jnp.max(jnp.abs(dec - full)) < 5e-4


def test_moe_decode_matches_forward_when_no_drops(rng):
    cfg = get_smoke_config("llama4-scout-17b-a16e").replace(capacity_factor=16.0)
    params = M.init_params(cfg, rng)
    tokens = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)
    full, _ = M.forward(cfg, params, {"tokens": tokens, "labels": tokens})
    dec, _ = _decode_all(cfg, params, tokens, 12, None)
    assert jnp.max(jnp.abs(dec - full)) < 5e-4


def test_sliding_window_decode_ring_buffer(rng):
    """Windowed ring-buffer decode == full forward with the same window."""
    cfg = get_smoke_config("llama3.2-3b").replace(
        num_layers=2, window_size=4,
        block_pattern=(("local", "mlp"),), decode_window=0)
    params = M.init_params(cfg, rng)
    B, T = 2, 12
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    full, _ = M.forward(cfg, params, {"tokens": tokens, "labels": tokens})
    dec, _ = _decode_all(cfg, params, tokens, T, None)  # local cache = window 4
    assert jnp.max(jnp.abs(dec - full)) < 5e-4


def test_causal_mask_no_future_leak(rng):
    cfg = get_smoke_config("qwen3-1.7b")
    params = M.init_params(cfg, rng)
    t1 = jax.random.randint(rng, (1, 10), 0, cfg.vocab_size)
    t2 = t1.at[:, -1].set((t1[:, -1] + 7) % cfg.vocab_size)
    l1, _ = M.forward(cfg, params, {"tokens": t1, "labels": t1})
    l2, _ = M.forward(cfg, params, {"tokens": t2, "labels": t2})
    # positions before the changed final token must be identical
    assert jnp.max(jnp.abs(l1[:, :-1] - l2[:, :-1])) < 1e-5


def test_encoder_is_bidirectional(rng):
    cfg = get_smoke_config("whisper-base")
    params = M.init_params(cfg, rng)
    frames = jax.random.normal(rng, (1, cfg.num_media_tokens, cfg.d_model))
    f2 = frames.at[:, -1].add(1.0)
    e1 = M.encode(cfg, params, frames)
    e2 = M.encode(cfg, params, f2)
    # changing the LAST frame changes EARLIER encoder outputs (bidirectional)
    assert jnp.max(jnp.abs(e1[:, 0] - e2[:, 0])) > 0


def test_vlm_cross_attention_sees_media(rng):
    cfg = get_smoke_config("llama-3.2-vision-90b")
    params = M.init_params(cfg, rng)
    tokens = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    media1 = jax.random.normal(rng, (1, cfg.num_media_tokens, cfg.d_model))
    l1, _ = M.forward(cfg, params, {"tokens": tokens, "media": media1})
    l2, _ = M.forward(cfg, params, {"tokens": tokens, "media": media1 + 1.0})
    assert jnp.max(jnp.abs(l1 - l2)) > 0


def test_rglru_assoc_scan_vs_sequential(rng):
    cfg = get_smoke_config("recurrentgemma-2b")
    stacked = M.init_params(cfg, rng)["decoder"][0][0]["mixer"]
    p = jax.tree.map(lambda a: a[0], stacked)  # first layer of the scan group
    x = jax.random.normal(rng, (2, 16, cfg.resolved_lru_width))
    h_par = R.rglru_scan(p, x)
    a, b = R._rglru_coeffs(p, x)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, h_seq = jax.lax.scan(step, jnp.zeros((2, x.shape[-1])),
                            (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    assert jnp.max(jnp.abs(h_par - h_seq.transpose(1, 0, 2))) < 1e-5


def test_rwkv_state_carry_matches_split_sequence(rng):
    """Running T steps then continuing == running T+K in one shot."""
    cfg = get_smoke_config("rwkv6-3b")
    params = M.init_params(cfg, rng)
    tokens = jax.random.randint(rng, (1, 16), 0, cfg.vocab_size)
    full, _ = M.forward(cfg, params, {"tokens": tokens, "labels": tokens})
    dec, _ = _decode_all(cfg, params, tokens, 16, None)
    assert jnp.max(jnp.abs(dec - full)) < 5e-4


def test_attention_window_mask():
    m = A.make_mask(6, 6, causal=True, window=3)
    # row 5 can see columns 3,4,5 only
    assert m[5].tolist() == [False, False, False, True, True, True]
    m2 = A.make_mask(4, 4, causal=True, window=0)
    assert m2[2].tolist() == [True, True, True, False]


def test_scan_vs_unrolled_layers_identical(rng):
    cfg = get_smoke_config("qwen3-1.7b").replace(num_layers=4)
    params = M.init_params(cfg, rng)
    tokens = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    l_scan, _ = M.forward(cfg, params, batch)
    l_unroll, _ = M.forward(cfg.replace(scan_layers=False), params, batch)
    assert jnp.max(jnp.abs(l_scan - l_unroll)) < 1e-5
