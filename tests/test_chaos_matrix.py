"""Chaos-matrix regression: a seeded grid of fault plans through a small job.

The contract under test is *termination with attribution*: whatever the
fault — kill, OOM, heartbeat drop, preemption, slow step — every run must
end (no hangs) with either SUCCEEDED or a fully classified set of
TaskDiagnostics. An unclassified failure or a hung AM is a bug regardless
of which fault produced it.
"""
import os
import threading
import time

import pytest

from repro.core import (
    ApplicationMaster,
    EventLog,
    FailureClass,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    NodeHealthTracker,
    RetryPolicy,
    TaskDiagnostics,
    job_spec_from_props,
    make_cluster,
)

pytestmark = pytest.mark.chaos

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))

# the matrix: (label, FaultSpec) — seeded via the plan, one fault per run
MATRIX = [
    ("kill@1", FaultSpec(FaultKind.KILL_TASK, task="worker:0", at_step=1)),
    ("kill@3", FaultSpec(FaultKind.KILL_TASK, task="worker:0", at_step=3)),
    ("kill_all_attempts", FaultSpec(FaultKind.KILL_TASK, task="worker:0",
                                    at_step=1, count=99)),
    ("oom@1", FaultSpec(FaultKind.OOM, task="worker:0", at_step=1)),
    ("oom@3", FaultSpec(FaultKind.OOM, task="worker:0", at_step=3)),
    ("hb_drop", FaultSpec(FaultKind.DROP_HEARTBEATS, task="worker:0",
                          attempt=1, duration_s=30.0)),
    ("preempt", FaultSpec(FaultKind.PREEMPT, task="worker:0", attempt=1,
                          after_s=0.02)),
    ("slow@1", FaultSpec(FaultKind.SLOW_STEP, task="worker:0", at_step=1,
                         delay_s=0.02)),
    ("slow+kill", FaultSpec(FaultKind.SLOW_STEP, task="worker:*",
                            delay_s=0.01)),
]


def _job(attempts=3):
    return job_spec_from_props({
        "tony.application.name": "matrix",
        "tony.application.max-attempts": str(attempts),
        "tony.worker.instances": "2",
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })


def _step_program(steps=6, work_s=0.01):
    def program(env, ctx):
        task_id = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        attempt = int(ctx.shared.get("attempt", 1))
        if not ctx.rendezvous(timeout=10):
            return 3
        if task_id != "worker:0":
            while not ctx.cancel.is_set() and not ctx.shared.get("done"):
                time.sleep(0.002)
            return 0
        start = int(ctx.shared.get("resume_step", 0))
        try:
            for step in range(start, steps):
                if ctx.cancel.is_set():
                    return 143
                ctx.step(task_id, attempt, step)
                if work_s:
                    time.sleep(work_s)
                if (step + 1) % 2 == 0:
                    ctx.shared["ckpt_step"] = step + 1
        finally:
            ctx.shared["done"] = True
        return 0

    return program


@pytest.mark.parametrize("label,spec", MATRIX, ids=[m[0] for m in MATRIX])
def test_matrix_terminates_with_classified_outcome(label, spec):
    plan = FaultPlan(seed=CHAOS_SEED).add(spec)
    if label == "slow+kill":   # compound plan: straggler AND a mid-run kill
        plan = plan.add(FaultSpec(FaultKind.KILL_TASK, task="worker:0",
                                  attempt=1, at_step=2))
    ev = EventLog()
    rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev))
    job = _job()
    app_id = rm.submit_application(job.name, job.queue)
    am = ApplicationMaster(
        rm, app_id, job, _step_program(),
        # fake clock: retries don't sleep, so the matrix stays fast
        retry_policy=RetryPolicy(max_attempts=3).with_clock(lambda s: None))
    am.heartbeat_timeout_s = 0.3   # hb_drop resolves quickly

    box = {}
    t = threading.Thread(target=lambda: box.update(result=am.run()),
                         daemon=True)
    t.start()
    t.join(45)
    assert not t.is_alive(), f"{label}: AM hung (no termination in 45s)"
    res = box["result"]

    # terminated with either success or fully attributed failure
    if not res.succeeded:
        assert res.diagnostics, f"{label}: failed with no diagnostics"
    for key, d in res.diagnostics.items():
        assert isinstance(d.classification, FailureClass), \
            f"{label}: unclassified diagnostic {key}"
        assert d.describe()
    # every failed attempt carries per-task attribution
    for rep in res.attempts:
        for tid in rep.failed_tasks:
            assert tid in rep.diagnostics, \
                f"{label}: attempt {rep.attempt} failed task {tid} unattributed"
    # nothing leaked, accounting intact
    assert not rm.live_containers(), f"{label}: leaked containers"
    assert rm.invariants_ok(), f"{label}: RM invariants violated"
    # chaos actually fired (the grid never silently no-ops)
    assert ev.count("chaos_injected") >= 1, f"{label}: fault never fired"


# ----------------------------------------------------------------------
# elastic × fault cells: the same termination-with-attribution contract,
# but the gang may legally *shrink* (min-instances) instead of dying —
# degraded completions must still be leak-free and fully evented.

ELASTIC_MATRIX = [
    # blacklist-forced shrink: a pre-struck node leaves room for only 2 of 3
    ("blacklist_shrink", None),
    # mid-attempt INFRA loss above the floor -> shed, attempt continues
    ("oom_shed", FaultSpec(FaultKind.OOM, task="worker:1", at_step=2)),
    # time-gated partition during rendezvous -> gang forms after the window
    ("partition_rendezvous", FaultSpec(FaultKind.PARTITION, src="worker:1",
                                       dst="worker:0", attempt=1,
                                       duration_s=0.3)),
    # step-gated partition -> ChaosPartition, TRANSIENT retry
    ("partition_step", FaultSpec(FaultKind.PARTITION, src="worker:0",
                                 dst="worker:1", attempt=1, at_step=2)),
    # allocation chaos mid-negotiation -> ride out or downsize, never leak
    ("fail_alloc", FaultSpec(FaultKind.FAIL_ALLOCATION, after_allocs=1,
                             count=2)),
    # preemption of an elastic member mid-attempt
    ("preempt_member", FaultSpec(FaultKind.PREEMPT, task="worker:1",
                                 attempt=1, after_s=0.02)),
]

_PRESTRIKE = TaskDiagnostics(task_id="worker:0", exit_status=137,
                             classification=FailureClass.INFRA,
                             message="pre-struck for the elastic matrix")


def _elastic_job(attempts=3):
    return job_spec_from_props({
        "tony.application.name": "elastic-matrix",
        "tony.application.max-attempts": str(attempts),
        "tony.worker.instances": "3",
        "tony.worker.min-instances": "2",
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })


def _gang_step_program(steps=6):
    """Every member steps (chaos can target any task id): worker:0 drives,
    the rest mirror its progress."""
    def program(env, ctx):
        task_id = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        attempt = int(ctx.shared.get("attempt", 1))
        if not ctx.rendezvous(timeout=10, exec_id=task_id, attempt=attempt):
            return 3
        if task_id == "worker:0":
            start = int(ctx.shared.get("resume_step", 0))
            try:
                for step in range(start, steps):
                    if ctx.cancel.is_set():
                        return 143
                    ctx.step(task_id, attempt, step)
                    time.sleep(0.005)
                    if (step + 1) % 2 == 0:
                        ctx.shared["ckpt_step"] = step + 1
            finally:
                ctx.shared["done"] = True
        else:
            my_step = -1
            while not ctx.cancel.is_set() and not ctx.shared.get("done"):
                lead = ctx.progress.get("worker:0", -1)
                if my_step < lead:
                    my_step += 1
                    ctx.step(task_id, attempt, my_step)
                else:
                    time.sleep(0.002)
        ctx.rendezvous(timeout=5, exec_id=task_id, attempt=attempt)
        return 0

    return program


def _run_elastic_cell(spec):
    plan = FaultPlan(seed=CHAOS_SEED)
    if spec is not None:
        plan = plan.add(spec)
    ev = EventLog()
    health = NodeHealthTracker(threshold=1, parole_s=3600.0, events=ev)
    rm = make_cluster(num_gpu_nodes=3, num_cpu_nodes=0, gpus_per_node=1,
                      memory_mb=2048, vcores=4, event_log=ev,
                      chaos=FaultInjector(plan, events=ev), health=health)
    if spec is None:   # blacklist-forced shrink cell
        health.record_failure("gpu-node-0", _PRESTRIKE)
    job = _elastic_job()
    app_id = rm.submit_application(job.name, job.queue)
    am = ApplicationMaster(
        rm, app_id, job, _gang_step_program(),
        retry_policy=RetryPolicy(max_attempts=3).with_clock(lambda s: None))
    am.NEGOTIATION_TIMEOUT_S = 0.4
    am.heartbeat_timeout_s = 1.0
    box = {}
    t = threading.Thread(target=lambda: box.update(result=am.run()),
                         daemon=True)
    t.start()
    t.join(45)
    assert not t.is_alive(), "elastic cell hung (no termination in 45s)"
    return box["result"], rm, ev


@pytest.mark.parametrize("label,spec", ELASTIC_MATRIX,
                         ids=[m[0] for m in ELASTIC_MATRIX])
def test_elastic_matrix_terminates_leak_free(label, spec):
    res, rm, ev = _run_elastic_cell(spec)
    if not res.succeeded:
        assert res.diagnostics, f"{label}: failed with no diagnostics"
    for key, d in res.diagnostics.items():
        assert isinstance(d.classification, FailureClass), \
            f"{label}: unclassified diagnostic {key}"
    # a degraded run must say so end to end: report, events, history inputs
    for rep in res.attempts:
        if rep.degraded:
            assert rep.attempt in res.resized_attempts, \
                f"{label}: degraded attempt missing from resized_attempts"
            assert ev.count("gang_resized") + ev.count("attempt_degraded") \
                >= 1, f"{label}: degraded without elastic events"
    assert not rm.live_containers(), f"{label}: leaked containers"
    assert rm.invariants_ok(), f"{label}: RM invariants violated"
    if spec is not None:
        assert ev.count("chaos_injected") >= 1, f"{label}: fault never fired"
    else:
        assert res.succeeded and res.resized_attempts, \
            f"{label}: blacklist shrink cell must complete degraded"


def test_elastic_matrix_is_deterministic_for_fixed_seed():
    """Same seed -> same elastic trajectory (shed cell run twice)."""
    def run_once():
        res, _rm, ev = _run_elastic_cell(
            FaultSpec(FaultKind.OOM, task="worker:1", at_step=2))
        return (res.final_status, len(res.attempts),
                {a: sorted(c.items()) for a, c in res.resized_attempts.items()},
                [r.shed_tasks for r in res.attempts])

    assert run_once() == run_once()


# ----------------------------------------------------------------------
# kill-during-async-checkpoint-write cell: the fault fires INSIDE the
# background writer (between staging and COMMIT), not at a step boundary.
# The publish-after-commit rule means the relaunch must resume from the
# last *committed* step — never the one whose write was killed.

def _async_ckpt_program(ckpt_dir, steps=6, ckpt_every=2):
    import numpy as np

    from repro.checkpoint import AsyncCheckpointer

    def program(env, ctx):
        task_id = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        attempt = int(ctx.shared.get("attempt", 1))
        if not ctx.rendezvous(timeout=10):
            return 3
        if task_id != "worker:0":
            while not ctx.cancel.is_set() and not ctx.shared.get("done"):
                time.sleep(0.002)
            return 0
        ckpt = AsyncCheckpointer(
            ckpt_dir,
            on_commit=lambda s, path, dur, nb: ctx.shared.__setitem__(
                "ckpt_step", s),
            chaos_hook=lambda s: ctx.chaos.check_ckpt_write(
                task_id, attempt, s))
        ctx.register_flusher(ckpt.flush)
        start = int(ctx.shared.get("resume_step", 0))
        state = {"w": np.full((4,), float(start), np.float32)}
        try:
            for step in range(start, steps):
                if ctx.cancel.is_set():
                    return 143
                ctx.step(task_id, attempt, step)
                state = {"w": state["w"] + 1.0}
                time.sleep(0.005)
                if (step + 1) % ckpt_every == 0:
                    # a deferred writer kill re-raises here (or at flush)
                    ckpt.save(state, step + 1)
            ckpt.flush()
        finally:
            ckpt.close()
            ctx.shared["done"] = True
        return 0

    return program


def _run_async_ckpt_kill_cell(tmp_path):
    from repro.checkpoint import latest_step

    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.KILL_TASK, task="worker:0", at_step=4,
                  in_ckpt_write=True))
    ev = EventLog()
    rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev))
    job = _job()
    app_id = rm.submit_application(job.name, job.queue)
    ckpt_dir = str(tmp_path / "ckpt")
    am = ApplicationMaster(
        rm, app_id, job, _async_ckpt_program(ckpt_dir),
        retry_policy=RetryPolicy(max_attempts=3).with_clock(lambda s: None))
    box = {}
    t = threading.Thread(target=lambda: box.update(result=am.run()),
                         daemon=True)
    t.start()
    t.join(45)
    assert not t.is_alive(), "async-ckpt kill cell hung"
    return box["result"], ev, latest_step(ckpt_dir)


def test_kill_during_async_ckpt_write_resumes_from_committed_step(tmp_path):
    res, ev, last = _run_async_ckpt_kill_cell(tmp_path)
    assert res.succeeded, res.diagnostics
    assert len(res.attempts) == 2
    # attempt 1 committed step 2, died inside the write of step 4 — so the
    # relaunch resumed from 2 (the last COMMIT), never from 4
    assert res.resumed_attempts == {2: 2}
    assert ev.count("chaos_injected") == 1
    assert ev.count("attempt_resumed") == 1
    assert last == 6                     # attempt 2 re-ran 2..6 and finished
    assert not res.attempts[-1].failed_tasks


def test_kill_during_async_ckpt_write_is_deterministic(tmp_path):
    def run_once(sub):
        res, ev, last = _run_async_ckpt_kill_cell(tmp_path / sub)
        return (res.final_status, len(res.attempts),
                dict(res.resumed_attempts), ev.count("chaos_injected"), last)

    assert run_once("a") == run_once("b")


def test_matrix_is_deterministic_for_fixed_seed():
    """Same seed -> same trajectory: run one cell twice, compare outcomes."""
    def run_once():
        plan = FaultPlan(seed=CHAOS_SEED).add(
            FaultSpec(FaultKind.KILL_TASK, task="worker:0", at_step=2))
        ev = EventLog()
        rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev))
        job = _job()
        app_id = rm.submit_application(job.name, job.queue)
        am = ApplicationMaster(
            rm, app_id, job, _step_program(),
            retry_policy=RetryPolicy(max_attempts=3).with_clock(lambda s: None))
        res = am.run()
        return (res.final_status, len(res.attempts),
                sorted((k, d.exception_type, d.classification.value)
                       for k, d in res.diagnostics.items()))

    assert run_once() == run_once()
