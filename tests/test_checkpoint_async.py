"""AsyncCheckpointer contract: depth-1 backpressure, publish-after-commit
ordering, sticky deferred writer errors, and the re-checkpoint swap windows
of ``save_pytree`` (a kill at any point leaves a committed copy visible).
"""
import os
import threading
import time

import numpy as np
import pytest

import repro.checkpoint.checkpointer as ck
from repro.checkpoint import (
    AsyncCheckpointer,
    Checkpointer,
    latest_step,
    restore_pytree,
    save_pytree,
    tree_nbytes,
)


def _tree(v: float):
    return {"w": np.full((8,), v, np.float32),
            "b": np.full((3,), v * 10, np.float32)}


def _wait_for(pred, timeout=5.0):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise AssertionError("condition never became true")
        time.sleep(0.005)


# ----------------------------------------------------------------------
# backpressure: the hand-off slot is depth 1

def test_second_save_blocks_until_writer_commits(tmp_path, monkeypatch):
    gate = threading.Event()
    in_writer = threading.Event()
    active = []
    real = ck.save_pytree

    def gated(tree, directory, step, pre_commit=None):
        active.append(step)
        assert len(active) == 1, "two writes in flight (depth > 1)"
        in_writer.set()
        gate.wait(5)
        try:
            return real(tree, directory, step, pre_commit=pre_commit)
        finally:
            active.remove(step)

    monkeypatch.setattr(ck, "save_pytree", gated)
    c = AsyncCheckpointer(str(tmp_path))
    try:
        t0 = time.monotonic()
        c.save(_tree(1.0), 1)            # hand-off only: returns immediately
        assert time.monotonic() - t0 < 1.0
        assert in_writer.wait(5)

        done2 = threading.Event()
        t = threading.Thread(target=lambda: (c.save(_tree(2.0), 2),
                                             done2.set()), daemon=True)
        t.start()
        time.sleep(0.15)
        # write 1 still in flight -> save 2 must be blocked, not queued
        assert not done2.is_set(), "second save returned while one in flight"
        gate.set()
        assert done2.wait(5), "second save never unblocked after commit"
        c.flush()
    finally:
        c.close()
    assert latest_step(str(tmp_path)) == 2


def test_publish_only_after_commit(tmp_path):
    staged = threading.Event()
    gate = threading.Event()
    commits = []

    def hook(step):                      # pre_commit: arrays staged, no COMMIT
        staged.set()
        gate.wait(5)

    c = AsyncCheckpointer(str(tmp_path), chaos_hook=hook,
                          on_commit=lambda s, p, dur, nb: commits.append(s))
    try:
        c.save(_tree(1.0), 2)
        assert staged.wait(5)
        # writer is paused between staging and COMMIT: nothing may be
        # published or visible yet
        assert commits == []
        assert latest_step(str(tmp_path)) is None
        gate.set()
        c.flush()
        assert commits == [2]
        assert latest_step(str(tmp_path)) == 2
    finally:
        c.close()


def test_writer_error_is_sticky_and_reraises_on_caller(tmp_path):
    boom = RuntimeError("chaos: killed inside the write")

    def hook(step):
        if step == 4:
            raise boom

    c = AsyncCheckpointer(str(tmp_path), chaos_hook=hook)
    try:
        c.save(_tree(1.0), 2)
        c.flush()                        # step 2 commits fine
        assert latest_step(str(tmp_path)) == 2
        c.save(_tree(2.0), 4)            # dies in the writer window
        with pytest.raises(RuntimeError, match="killed inside"):
            c.flush()
        with pytest.raises(RuntimeError, match="killed inside"):
            c.save(_tree(3.0), 6)        # sticky: the task must die, not retry
        # the failed write never became visible
        assert latest_step(str(tmp_path)) == 2
    finally:
        c.close()


def test_close_drains_pending_write_and_rejects_new_saves(tmp_path):
    c = AsyncCheckpointer(str(tmp_path))
    c.save(_tree(1.0), 2)
    c.close()                            # graceful: pending write commits
    c.close()                            # idempotent
    assert latest_step(str(tmp_path)) == 2
    with pytest.raises(RuntimeError, match="closed"):
        c.save(_tree(2.0), 4)


def test_async_matches_sync_on_disk(tmp_path):
    tree = _tree(3.5)
    sync_dir, async_dir = str(tmp_path / "s"), str(tmp_path / "a")
    Checkpointer(sync_dir).save(tree, 7)
    c = AsyncCheckpointer(async_dir)
    c.save(tree, 7)
    c.flush()
    c.close()
    a = restore_pytree(_tree(0.0), async_dir, 7)
    s = restore_pytree(_tree(0.0), sync_dir, 7)
    for k in tree:
        np.testing.assert_array_equal(a[k], s[k])
    assert tree_nbytes(a) == tree_nbytes(tree)


# ----------------------------------------------------------------------
# re-checkpoint swap windows: overwriting step N must never lose step N

def test_kill_at_replace_keeps_old_committed_copy(tmp_path, monkeypatch):
    d = str(tmp_path)
    save_pytree(_tree(1.0), d, 5)
    monkeypatch.setattr(ck.shutil, "rmtree", lambda *a, **k: None)
    monkeypatch.setattr(ck.os, "replace",
                        lambda *a: (_ for _ in ()).throw(OSError("killed")))
    with pytest.raises(OSError):
        save_pytree(_tree(2.0), d, 5)    # dies after the old dir moved aside
    monkeypatch.undo()
    # the aside copy still counts as committed and restores the OLD content
    assert latest_step(d) == 5
    got = restore_pytree(_tree(0.0), d, 5)
    np.testing.assert_array_equal(got["w"], _tree(1.0)["w"])


def test_kill_during_aside_cleanup_shows_new_content(tmp_path, monkeypatch):
    d = str(tmp_path)
    save_pytree(_tree(1.0), d, 5)
    monkeypatch.setattr(ck.shutil, "rmtree", lambda *a, **k: None)
    save_pytree(_tree(2.0), d, 5)        # replace lands, cleanup "killed"
    monkeypatch.undo()
    assert latest_step(d) == 5
    got = restore_pytree(_tree(0.0), d, 5)
    np.testing.assert_array_equal(got["w"], _tree(2.0)["w"])
    # gc clears the now-redundant aside once the final dir is committed
    Checkpointer(d)._gc()
    assert not [e for e in os.listdir(d) if e.startswith(".aside-")]
