"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py forces
512 placeholder devices (in its own process)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)
