"""End-to-end system tests: real JAX training jobs through the full TonY path
(client -> RM -> AM -> executors -> train loop), including checkpoint-restore
fault tolerance — the paper's §2.2/§3 behaviour."""
import os

import pytest

from repro.configs import get_config
from repro.core import TonYClient, YarnLikeBackend, job_spec_from_props, make_cluster
from repro.launch.programs import make_train_program

CFG = get_config("tony-paper-mlp").replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=128, max_position=64)


def _job(workers=2, ps=1):
    props = {
        "tony.application.name": "e2e",
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "2048",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
        "tony.ps.instances": str(ps),
        "tony.ps.memory": "1024",
        "tony.ps.node-label": "highmem",
    }
    return job_spec_from_props(props)


def test_e2e_training_job_succeeds_and_loss_drops(tmp_path):
    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    losses = []
    prog = make_train_program(
        CFG, steps=25, batch_size=8, seq_len=32,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=10,
        on_step=lambda s, m: losses.append(m["loss"]))
    res = client.run_and_wait(_job(), prog, timeout=300)
    assert res.succeeded and len(res.attempts) == 1
    assert losses[0] > losses[-1]
    assert os.path.exists(tmp_path / "ck")
    # chief reported real metrics through the executor
    mkeys = [k for k in res.metrics if k.endswith("worker:0")]
    assert mkeys and res.metrics[mkeys[0]]["steps"] == 25.0


def test_e2e_fault_tolerance_restores_from_checkpoint(tmp_path):
    """Kill the chief mid-run on attempt 1; AM relaunches; training resumes
    from the last checkpoint, not from scratch (the paper's §2.2 contract)."""
    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    seen_steps = []
    prog = make_train_program(
        CFG, steps=20, batch_size=8, seq_len=32,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=5,
        fail_at=(1, 13),  # crash attempt 1 at step 13 (after the step-10 ckpt)
        on_step=lambda s, m: seen_steps.append(s))
    res = client.run_and_wait(_job(), prog, timeout=300)
    assert res.succeeded
    assert len(res.attempts) == 2
    assert "worker:0" in res.attempts[0].failed_tasks
    # the crash was attributed: real traceback + TRANSIENT classification,
    # and the retry that saved the job is visible in the event log
    diag = res.diagnostics["a1/worker:0"]
    assert diag.classification.value == "TRANSIENT"
    assert diag.exception_type == "RuntimeError"
    assert "injected transient failure" in diag.traceback
    assert rm.events.count("retry_scheduled") == 1
    # attempt 2 resumed at 10 (the checkpoint), not 0
    restart_points = [s for i, s in enumerate(seen_steps[1:], 1)
                      if s <= seen_steps[i - 1]]
    assert restart_points == [10]
    assert max(seen_steps) == 19
    # the relaunch negotiated fresh containers
    assert rm.events.count("container_allocated") == 6
    assert rm.invariants_ok()


def test_e2e_new_cluster_spec_each_attempt(tmp_path):
    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    prog = make_train_program(
        CFG, steps=8, batch_size=4, seq_len=16,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=4, fail_at=(1, 2))
    res = client.run_and_wait(_job(workers=1, ps=1), prog, timeout=300)
    assert res.succeeded
    s1 = res.attempts[0].cluster_spec
    s2 = res.attempts[1].cluster_spec
    assert s1 is not None and s2 is not None
    assert s1 != s2  # fresh ports/containers -> new global spec (paper §2.2)
