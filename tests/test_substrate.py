"""Substrate tests: checkpointer, data pipeline, optimizer, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step
from repro.data import FileTokenDataset, SyntheticLMDataset
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# ----------------------------------------------------------------------
# Checkpointer


def test_checkpoint_latest_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones((4,), np.int32)}}
    for s in (5, 10, 15):
        ck.save(tree, s)
    assert ck.latest_step() == 15
    files = sorted(os.listdir(tmp_path))
    assert files == ["step_00000010", "step_00000015"]  # gc kept 2
    back = ck.restore(tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save({"a": np.ones((2, 2))}, 1)
    with pytest.raises(ValueError, match="shape mismatch"):
        ck.restore({"a": np.ones((3, 3))})


def test_checkpoint_gc_tolerates_junk_and_half_written(tmp_path):
    """_gc and latest_step skip non-step entries, staging dirs and steps
    missing their COMMIT marker (a writer killed mid-checkpoint) instead of
    crashing or resuming from a torn checkpoint."""
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": np.ones((2,), np.float32)}
    (tmp_path / "notes.txt").write_text("user junk")
    (tmp_path / ".tmp-step_00000042-abc").mkdir()      # abandoned staging dir
    half = tmp_path / "step_00000099"                  # killed mid-write:
    half.mkdir()                                       # arrays, no COMMIT
    np.savez(half / "arrays.npz", a=np.zeros((2,), np.float32))
    for s in (1, 2, 3):
        ck.save(tree, s)                               # _gc runs each save
    assert ck.latest_step() == 3                       # 99 is invisible
    back = ck.restore(tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    left = sorted(os.listdir(tmp_path))
    assert "notes.txt" in left and "step_00000099" in left  # skipped, kept
    assert "step_00000001" not in left                 # gc dropped oldest
    with pytest.raises(FileNotFoundError):             # uncommitted = absent
        ck.restore(tree, 99)


def test_checkpoint_reads_legacy_flat_format(tmp_path):
    """Pre-PR-7 flat ckpt_*.npz files still restore and participate in gc."""
    tree = {"a": np.arange(4, dtype=np.float32)}
    np.savez(str(tmp_path / "ckpt_00000005.npz"), a=tree["a"] * 2)
    assert latest_step(str(tmp_path)) == 5
    ck = Checkpointer(str(tmp_path), keep=2)
    back = ck.restore(tree, 5)
    np.testing.assert_array_equal(back["a"], tree["a"] * 2)
    ck.save(tree, 7)                                   # new format on top
    assert ck.latest_step() == 7
    ck.save(tree, 9)                                   # keep=2 -> legacy gc'd
    assert sorted(os.listdir(tmp_path)) == ["step_00000007", "step_00000009"]


# ----------------------------------------------------------------------
# Data pipeline


def test_synthetic_dataset_deterministic_and_restartable():
    d1 = SyntheticLMDataset(4, 32, 1000, seed=7)
    d2 = SyntheticLMDataset(4, 32, 1000, seed=7)
    for _ in range(3):
        d1.next_batch()
    b3 = d1.next_batch()
    d2.load_state_dict({"step": 3})
    b3b = d2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], b3b["tokens"])
    np.testing.assert_array_equal(b3["labels"], b3b["labels"])
    assert b3["tokens"].shape == (4, 32)
    # labels are next-token shifted
    full_like = b3["tokens"][:, 1:]
    np.testing.assert_array_equal(full_like, b3["labels"][:, :-1])


def test_synthetic_dataset_is_learnable_structure():
    d = SyntheticLMDataset(8, 64, 500, seed=0, noise_prob=0.0)
    b = d.next_batch()
    # with zero noise each row is periodic with the motif length
    row = b["tokens"][0]
    assert (row[:8] == row[8:16]).all()


def test_file_dataset_roundtrip(tmp_path):
    path = str(tmp_path / "corpus.bin")
    tokens = np.arange(10_000, dtype=np.int32) % 777
    FileTokenDataset.write_corpus(path, tokens)
    ds = FileTokenDataset(path, batch_size=2, seq_len=16)
    b = ds.next_batch()
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][0], tokens[:16])
    np.testing.assert_array_equal(b["labels"][0], tokens[1:17])


# ----------------------------------------------------------------------
# Optimizer


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip_norm=0.0)
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(opt, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clipping():
    params = {"w": jnp.zeros(3)}
    opt = AdamWConfig(lr=1.0, grad_clip_norm=1.0, weight_decay=0.0)
    state = adamw_init(params)
    huge = {"w": jnp.full(3, 1e6)}
    _, _, metrics = adamw_update(opt, huge, state, params)
    assert metrics["grad_norm"] > 1e5  # reported raw norm


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(f(jnp.array(0))) == 0.0
    assert abs(float(f(jnp.array(10))) - 1.0) < 1e-6
    assert float(f(jnp.array(100))) < 1e-6
    assert float(f(jnp.array(55))) < float(f(jnp.array(20)))


# ----------------------------------------------------------------------
# Train loop integration: loss decreases on learnable data (small model)


def test_training_reduces_loss(rng):
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.distributed.steps import init_train_state, make_train_fn
    from repro.launch.mesh import make_local_mesh, set_mesh

    cfg = get_config("tony-paper-mlp").replace(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, max_position=64)
    data = SyntheticLMDataset(8, 32, cfg.vocab_size, seed=1)
    mesh = make_local_mesh()
    with set_mesh(mesh):
        fn, _ = make_train_fn(cfg, mesh, "fsdp_tp",
                              shape=ShapeConfig("t", 32, 8, "train"))
        state = init_train_state(cfg, rng)
        losses = []
        for _ in range(30):
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            state, m = fn(state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]
