"""Chaos-injection + checkpoint-aware recovery + node blacklisting tests.

Everything here runs against a *seeded* FaultPlan (CHAOS_SEED, overridable in
CI) so injected-fault runs are bit-for-bit reproducible: same plan, same
failures, same recovery trajectory.
"""
import os
import time

import pytest

from repro.core import (
    AllocationError,
    ApplicationMaster,
    ChaosOOM,
    ContainerRequest,
    EventLog,
    FailureClass,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    JobHistoryServer,
    MetricsAnalyzer,
    Node,
    NodeHealthTracker,
    Resource,
    ResourceManager,
    RetryPolicy,
    TaskDiagnostics,
    TonYClient,
    YarnLikeBackend,
    classify_exception,
    job_spec_from_props,
    make_cluster,
)
from repro.core.failures import diagnose_exception, is_oom_signature

pytestmark = pytest.mark.chaos

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))


def _job(workers=2, attempts=3):
    return job_spec_from_props({
        "tony.application.name": "chaos",
        "tony.application.max-attempts": str(attempts),
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })


def make_step_program(steps: int, ckpt_every: int = 2, work_s: float = 0.0,
                      trace: list | None = None):
    """Minimal stand-in for the JAX train loop: steps through the chaos
    hook, honors the AM's resume_step, and publishes completed checkpoints
    — the full resume contract without JIT compile time."""

    def program(env, ctx):
        task_id = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        attempt = int(ctx.shared.get("attempt", 1))
        if not ctx.rendezvous(timeout=10):
            return 3
        if task_id != "worker:0":
            while not ctx.cancel.is_set() and not ctx.shared.get("done"):
                time.sleep(0.002)
            return 0
        start = int(ctx.shared.get("resume_step", 0))
        try:
            for step in range(start, steps):
                if ctx.cancel.is_set():
                    return 143
                ctx.chaos.check_step(task_id, attempt, step)
                if trace is not None:
                    trace.append((attempt, step))
                if work_s:
                    time.sleep(work_s)
                if (step + 1) % ckpt_every == 0:
                    ctx.shared["ckpt_step"] = step + 1
        finally:
            ctx.shared["done"] = True
        return 0

    return program


def _chaos_cluster(plan, *, health=None, **cluster_kw):
    ev = EventLog()
    rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev),
                      health=health, **cluster_kw)
    return rm, ev


# ----------------------------------------------------------------------
# Plan + classification units


def test_fault_plan_seeded_generation_is_deterministic():
    p1 = FaultPlan.random_plan(CHAOS_SEED, steps=50, n_faults=4)
    p2 = FaultPlan.random_plan(CHAOS_SEED, steps=50, n_faults=4)
    assert p1 == p2 and len(p1.faults) == 4
    assert FaultPlan.random_plan(CHAOS_SEED + 1, steps=50, n_faults=4) != p1


def test_fault_spec_task_patterns():
    s = FaultSpec(FaultKind.KILL_TASK, task="worker:*")
    assert s.matches_task("worker:0") and s.matches_task("worker:7")
    assert not s.matches_task("ps:0")
    assert FaultSpec(FaultKind.KILL_TASK, task="*").matches_task("ps:3")
    assert FaultSpec(FaultKind.KILL_TASK, attempt=2).matches_attempt(2)
    assert not FaultSpec(FaultKind.KILL_TASK, attempt=2).matches_attempt(1)


def test_oom_signatures_classified_infra_with_flag():
    d = diagnose_exception("worker:0", MemoryError("alloc failed"))
    assert d.classification is FailureClass.INFRA and d.oom
    try:
        raise RuntimeError("RESOURCE_EXHAUSTED: Out of memory while trying "
                           "to allocate 17179869184 bytes")
    except RuntimeError as e:
        d2 = diagnose_exception("worker:1", e)
    assert d2.classification is FailureClass.INFRA and d2.oom
    assert "(OOM)" in d2.describe() and d2.to_dict()["oom"] is True
    assert classify_exception(
        "RuntimeError", "CUDA_ERROR_OUT_OF_MEMORY: out of memory"
    ) is FailureClass.INFRA
    assert is_oom_signature("ChaosOOM", "")
    # plain crashes stay TRANSIENT, ImportError stays FATAL_USER
    d3 = diagnose_exception("w", RuntimeError("plain crash"))
    assert d3.classification is FailureClass.TRANSIENT and not d3.oom
    assert classify_exception(ImportError("x")) is FailureClass.FATAL_USER


def test_injector_oom_raises_xla_style_message():
    inj = FaultInjector(FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.OOM, task="worker:0", at_step=3)))
    inj.check_step("worker:0", 1, 2)  # no-op: wrong step
    with pytest.raises(ChaosOOM, match="RESOURCE_EXHAUSTED"):
        inj.check_step("worker:0", 1, 3)
    inj.check_step("worker:0", 1, 3)  # count budget spent: fires once


# ----------------------------------------------------------------------
# NodeHealthTracker units (fake clock)


def _infra_diag(oom=False):
    return TaskDiagnostics("worker:0", 1, FailureClass.INFRA,
                           exception_type="ChaosOOM" if oom else "",
                           message="boom", oom=oom)


def test_node_health_blacklist_and_parole():
    t = [0.0]
    ev = EventLog()
    tr = NodeHealthTracker(threshold=2, parole_s=10.0, clock=lambda: t[0],
                           events=ev)
    assert not tr.record_failure("n0", _infra_diag())
    assert tr.record_failure("n0", _infra_diag(oom=True))  # tipped over
    assert tr.is_blacklisted("n0") and tr.blacklisted() == ["n0"]
    assert ev.count("node_blacklisted") == 1
    assert ev.of_kind("node_blacklisted")[0].payload["oom"] is True
    t[0] = 9.9
    assert tr.is_blacklisted("n0")
    t[0] = 10.0  # parole: allowed back, one strike from re-blacklist
    assert not tr.is_blacklisted("n0")
    assert ev.count("node_paroled") == 1
    assert tr.record_failure("n0", _infra_diag())  # single strike re-trips
    assert tr.is_blacklisted("n0")


def test_node_health_parole_edge_restrike_vs_clean_wipe():
    """The parole contract's two exits: a paroled node is ONE strike from
    re-blacklisting (not a clean slate), but a clean attempt wipes every
    strike — including the parole residue."""
    t = [0.0]
    ev = EventLog()
    tr = NodeHealthTracker(threshold=2, parole_s=10.0, clock=lambda: t[0],
                           events=ev)
    tr.record_failure("n0", _infra_diag())
    assert tr.record_failure("n0", _infra_diag())      # blacklisted
    t[0] = 10.0
    assert not tr.is_blacklisted("n0")                 # paroled
    assert tr.snapshot()["failures"]["n0"] == tr.threshold - 1
    # exit A: one more INFRA strike re-blacklists immediately
    assert tr.record_failure("n0", _infra_diag())
    assert tr.is_blacklisted("n0")
    assert ev.count("node_blacklisted") == 2 and ev.count("node_paroled") == 1
    # exit B (fresh tracker): a clean attempt after parole wipes strikes, so
    # one later strike must NOT re-blacklist (it is strike 1 of 2 again)
    t2 = [0.0]
    tr2 = NodeHealthTracker(threshold=2, parole_s=10.0, clock=lambda: t2[0])
    tr2.record_failure("n1", _infra_diag())
    tr2.record_failure("n1", _infra_diag())
    t2[0] = 10.0
    assert not tr2.is_blacklisted("n1")
    tr2.record_success("n1")                           # clean attempt
    assert tr2.snapshot()["failures"] == {}
    assert not tr2.record_failure("n1", _infra_diag())
    assert not tr2.is_blacklisted("n1")
    assert tr2.record_failure("n1", _infra_diag())     # second strike trips


def test_node_health_only_infra_counts_and_success_resets():
    tr = NodeHealthTracker(threshold=1)
    transient = TaskDiagnostics("w", 1, FailureClass.TRANSIENT, message="x")
    fatal = TaskDiagnostics("w", 1, FailureClass.FATAL_USER, message="x")
    assert not tr.record_failure("n0", transient)
    assert not tr.record_failure("n0", fatal)
    assert not tr.is_blacklisted("n0")
    tr2 = NodeHealthTracker(threshold=2)
    tr2.record_failure("n1", _infra_diag())
    tr2.record_success("n1")                      # clean attempt wipes strikes
    assert not tr2.record_failure("n1", _infra_diag())
    assert not tr2.is_blacklisted("n1")


# ----------------------------------------------------------------------
# Tentpole: seeded kill -> next attempt resumes from the checkpoint


def test_chaos_kill_resumes_next_attempt_from_checkpoint():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.KILL_TASK, task="worker:0", attempt=1, at_step=5))
    rm, ev = _chaos_cluster(plan)
    trace = []
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        _job(), make_step_program(8, ckpt_every=2, trace=trace), timeout=60)
    assert res.succeeded and len(res.attempts) == 2
    # attempt 1 died at step 5 with a classified, chaos-attributed failure
    d = res.diagnostics["a1/worker:0"]
    assert d.exception_type == "ChaosKill"
    assert d.classification is FailureClass.TRANSIENT
    assert ev.count("chaos_injected") == 1
    assert ev.of_kind("chaos_injected")[0].payload["fault"] == "kill_task"
    assert ev.of_kind("chaos_injected")[0].payload["seed"] == CHAOS_SEED
    # attempt 2 resumed from the step-4 checkpoint, not step 0
    assert res.attempts[0].checkpoint_step == 4
    assert res.attempts[1].resume_step == 4
    assert res.resumed_attempts == {2: 4}
    resumed = ev.of_kind("attempt_resumed")
    assert len(resumed) == 1 and resumed[0].payload["resume_step"] == 4
    a2_steps = [s for a, s in trace if a == 2]
    assert a2_steps and a2_steps[0] == 4 and min(a2_steps) > 0
    assert not rm.live_containers() and rm.invariants_ok()


def test_chaos_kill_resumes_real_training_from_checkpoint(tmp_path):
    """The full JAX path: chaos kills the chief at step 6; attempt 2
    restores model+optimizer state via Checkpointer.restore from step 4 and
    trains on (training step counter > 0 on attempt 2)."""
    from repro.configs import get_config
    from repro.launch.programs import make_train_program

    cfg = get_config("tony-paper-mlp").replace(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, max_position=64)
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.KILL_TASK, task="worker:0", attempt=1, at_step=6))
    rm, ev = _chaos_cluster(plan)
    seen = []
    prog = make_train_program(
        cfg, steps=10, batch_size=4, seq_len=16,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=4,
        on_step=lambda s, m: seen.append(s))
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(_job(), prog,
                                                       timeout=300)
    assert res.succeeded and len(res.attempts) == 2
    assert res.diagnostics["a1/worker:0"].exception_type == "ChaosKill"
    # AM-driven resume: attempt 2's first training step is 4, not 0
    assert res.resumed_attempts == {2: 4}
    a2_first = seen[seen.index(5) + 1]   # first step after attempt 1's last
    assert a2_first == 4 and a2_first > 0
    assert max(seen) == 9
    assert ev.count("attempt_resumed") == 1


# ----------------------------------------------------------------------
# Tentpole: K INFRA failures on one node -> blacklisted, reallocation avoids


def test_node_blacklisted_after_k_oom_failures_allocations_avoid_it():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.OOM, task="worker:0", attempt=0, at_step=2,
                  count=2))
    ev = EventLog()
    health = NodeHealthTracker(threshold=2, parole_s=600.0, events=ev)
    rm = make_cluster(num_gpu_nodes=3, num_cpu_nodes=1, event_log=ev,
                      chaos=FaultInjector(plan, events=ev), health=health)
    job = _job(attempts=3)
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        job, make_step_program(4, ckpt_every=2), timeout=60)
    assert res.succeeded and len(res.attempts) == 3
    # both OOMs were INFRA-classified with the oom flag
    for key in ("a1/worker:0", "a2/worker:0"):
        assert res.diagnostics[key].classification is FailureClass.INFRA
        assert res.diagnostics[key].oom
    # the node that hosted worker:0 ate both OOMs and got blacklisted
    bad = res.attempts[0].nodes["worker:0"]
    assert res.attempts[1].nodes["worker:0"] == bad
    bl = ev.of_kind("node_blacklisted")
    assert len(bl) == 1 and bl[0].payload["node"] == bad
    assert bl[0].payload["infra_failures"] == 2 and bl[0].payload["oom"]
    # attempt 3's allocations exclude the blacklisted node
    assert bad not in res.attempts[2].nodes.values()
    assert res.blacklisted_nodes == [bad]
    # recovery was checkpoint-aware throughout (resume from step 2)
    assert res.resumed_attempts == {2: 2, 3: 2}
    # history summary surfaces blacklist + resumes; timeline carries the
    # recovery events
    hist = JobHistoryServer()
    hist.record(job, res)
    s = hist.summary(res.app_id)
    assert s["blacklisted_nodes"] == [bad]
    assert s["resumed_attempts"] == {2: 2, 3: 2}
    assert s["diagnostics"]["a1/worker:0"]["oom"] is True
    timeline_kinds = {e.kind for e in ev.failure_timeline()}
    assert {"node_blacklisted", "attempt_resumed",
            "chaos_injected"} <= timeline_kinds
    assert any(g.kind == "oom" for g in MetricsAnalyzer().analyze(job, res))
    assert not rm.live_containers() and rm.invariants_ok()


# ----------------------------------------------------------------------
# Chaos heartbeat drop + preemption


def test_chaos_heartbeat_drop_becomes_classified_timeout():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.DROP_HEARTBEATS, task="worker:0", attempt=1,
                  duration_s=30.0))
    ev = EventLog()
    rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev))
    job = _job(attempts=1)
    app_id = rm.submit_application(job.name, job.queue)

    def long_running(env, ctx):
        ctx.rendezvous(timeout=10)
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline and not ctx.cancel.is_set():
            time.sleep(0.01)
        return 0

    am = ApplicationMaster(rm, app_id, job, long_running,
                           retry_policy=RetryPolicy(max_attempts=1))
    am.heartbeat_timeout_s = 0.25
    res = am.run()
    assert not res.succeeded
    d = res.diagnostics["a1/worker:0"]
    assert d.exception_type == "HeartbeatTimeout"
    assert d.classification is FailureClass.TRANSIENT
    assert ev.count("heartbeat_lost") == 1
    assert ev.of_kind("chaos_injected")[0].payload["fault"] == "drop_heartbeats"
    assert not rm.live_containers() and rm.invariants_ok()


def test_chaos_preemption_counts_infra_and_job_recovers():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.PREEMPT, task="worker:0", attempt=1,
                  after_s=0.05))
    ev = EventLog()
    health = NodeHealthTracker(threshold=1, parole_s=600.0, events=ev)
    rm = make_cluster(event_log=ev, chaos=FaultInjector(plan, events=ev),
                      health=health)
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        _job(), make_step_program(60, ckpt_every=10, work_s=0.005),
        timeout=60)
    assert res.succeeded and len(res.attempts) == 2
    d = res.diagnostics["a1/worker:0"]
    assert d.exit_status == 137 and d.classification is FailureClass.INFRA
    assert ev.of_kind("chaos_injected")[0].payload["fault"] == "preempt"
    # the preemption counted as an INFRA strike against the hosting node
    # (threshold=1 -> immediate blacklist) and attempt 2 avoided it
    bad = res.attempts[0].nodes["worker:0"]
    bl = ev.of_kind("node_blacklisted")
    assert len(bl) == 1 and bl[0].payload["node"] == bad
    assert bad not in res.attempts[1].nodes.values()
    assert res.blacklisted_nodes == [bad]
    assert not rm.live_containers() and rm.invariants_ok()


# ----------------------------------------------------------------------
# Satellite: RM under chaos allocation failures + unfittable gangs


def test_chaos_allocation_failure_mid_gang_leaks_nothing():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.FAIL_ALLOCATION, after_allocs=1, count=1))
    ev = EventLog()
    rm = ResourceManager([Node(f"n{i}", Resource(8192, 8, 4)) for i in range(2)],
                         event_log=ev, chaos=FaultInjector(plan, events=ev))
    app = rm.submit_application("gang", "default")
    req = ContainerRequest(Resource(1024, 1, 1))
    # first allocate succeeds, second is chaos-failed -> the whole gang
    # rolls back and nothing leaks
    with pytest.raises(AllocationError, match="chaos"):
        rm.allocate_many(app, req, 2)
    assert not rm.live_containers()
    assert rm.invariants_ok()
    assert ev.count("allocation_chaos_failed") == 1
    # chaos budget spent: the retry succeeds
    got = rm.allocate_many(app, req, 2)
    assert len(got) == 2 and rm.invariants_ok()
    for c in got:
        rm.release(c.container_id)
    assert not rm.live_containers() and rm.invariants_ok()


def test_am_negotiation_rides_through_chaos_allocation_failure():
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.FAIL_ALLOCATION, count=1))
    rm, ev = _chaos_cluster(plan)
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        _job(), make_step_program(2, ckpt_every=1), timeout=60)
    # a single injected allocation failure is absorbed inside the
    # negotiation window without burning an app attempt
    assert res.succeeded and len(res.attempts) == 1
    assert ev.count("allocation_chaos_failed") == 1
    assert ev.count("negotiation_waiting") == 1
    assert not rm.live_containers() and rm.invariants_ok()


def test_gang_that_cannot_fit_fails_cleanly_without_leaks():
    ev = EventLog()
    rm = make_cluster(num_gpu_nodes=1, num_cpu_nodes=0, gpus_per_node=2,
                      event_log=ev)
    job = _job(workers=4, attempts=1)       # 4 GPU workers, cluster has 2
    app_id = rm.submit_application(job.name, job.queue)
    am = ApplicationMaster(rm, app_id, job, make_step_program(2),
                           retry_policy=RetryPolicy(max_attempts=1))
    am.NEGOTIATION_TIMEOUT_S = 0.3
    res = am.run()
    assert not res.succeeded
    assert res.attempts[0].failed_tasks == ["__allocation__"]
    # try_preempt_for found no over-share victims: nothing was preempted
    assert ev.count("container_preempted") == 0
    assert not rm.live_containers()
    assert rm.invariants_ok()


class _ModuleProxy:
    """Stand-in for a module the checkpointer imported, with chosen
    attributes overridden — patches stay local to the checkpointer module
    instead of mutating numpy/json/os globally."""

    def __init__(self, mod, **overrides):
        self._mod = mod
        self._overrides = overrides

    def __getattr__(self, name):
        if name in self._overrides:
            return self._overrides[name]
        return getattr(self._mod, name)


def test_checkpoint_kill_points_never_expose_uncommitted_step(tmp_path,
                                                              monkeypatch):
    """Deterministic twin of the hypothesis property (test_property.py):
    hard-kill the checkpoint writer at each op inside save_pytree — during
    the array write, during the COMMIT-marker write, and at the atomic
    rename — leaving its debris behind (a real SIGKILL runs no finally);
    latest_step/restore must never observe the uncommitted step."""
    import json
    import shutil

    import numpy as np

    import repro.checkpoint.checkpointer as ck
    from repro.core import ChaosKill

    tree1 = {"w": np.ones((2, 2), np.float32)}
    tree2 = {"w": np.full((2, 2), 7.0, np.float32)}

    def killer(*a, **k):
        raise ChaosKill("chaos: checkpoint writer killed mid-op")

    kill_points = {
        "during_array_write": ("np", np, {"savez": killer}),
        "during_commit_write": ("json", json, {"dump": killer}),
        "at_atomic_rename": ("os", os, {"replace": killer}),
    }
    for label, (attr, mod, over) in kill_points.items():
        d = str(tmp_path / label)
        ck.save_pytree(tree1, d, 1)            # committed baseline
        monkeypatch.setattr(ck, attr, _ModuleProxy(mod, **over))
        # a hard kill runs no cleanup: keep the staging debris on disk
        monkeypatch.setattr(ck, "shutil",
                            _ModuleProxy(shutil, rmtree=lambda *a, **k: None))
        with pytest.raises(ChaosKill):
            ck.save_pytree(tree2, d, 2)
        monkeypatch.undo()
        # debris may exist, but the committed view is untouched
        assert ck.latest_step(d) == 1, label
        assert not ck.is_committed(d, 2), label
        back = ck.restore_pytree({"w": np.zeros((2, 2), np.float32)}, d)
        np.testing.assert_array_equal(back["w"], tree1["w"])
    # a marker-less step dir (manual copy, interrupted writer) is equally
    # invisible to latest_step and restore
    d = str(tmp_path / "during_array_write")
    os.makedirs(os.path.join(d, "step_00000009"), exist_ok=True)
    assert ck.latest_step(d) == 1
    with pytest.raises(FileNotFoundError):
        ck.restore_pytree(tree1, d, 9)


def test_try_preempt_for_under_chaos_allocation_failures():
    # after_allocs=2: let the hog's two allocations through, chaos-fail the
    # prod queue's first ask
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.FAIL_ALLOCATION, after_allocs=2, count=1))
    ev = EventLog()
    rm = ResourceManager([Node("n0", Resource(10_000, 100, 0))],
                         queues={"prod": 0.8, "adhoc": 0.2}, elastic=True,
                         event_log=ev, chaos=FaultInjector(plan, events=ev))
    a_hog = rm.submit_application("hog", "adhoc")
    hogs = [rm.allocate(a_hog, ContainerRequest(Resource(4000, 10, 0)))
            for _ in range(2)]
    assert rm.queue_over_share("adhoc")
    a_prod = rm.submit_application("p", "prod")
    ask = ContainerRequest(Resource(6000, 10, 0))
    with pytest.raises(AllocationError, match="chaos"):   # injected failure
        rm.allocate(a_prod, ask)
    assert rm.invariants_ok() and len(rm.live_containers()) == 2
    n = rm.try_preempt_for(a_prod, ask)
    assert n >= 1 and rm.invariants_ok()
    c = rm.allocate(a_prod, ask)                          # chaos budget spent
    assert c is not None and rm.invariants_ok()
    # conservation held across chaos + preemption: no leaked containers
    live = rm.live_containers()
    assert len(live) == len(hogs) - n + 1
