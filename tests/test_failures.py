"""Failure-diagnostics + retry-policy subsystem tests: classification,
fail-fast on user errors, backoff on transient faults, heartbeat-timeout
attribution, and the history server's "why did my job fail" answer."""
import time

from repro.core import (
    ApplicationMaster,
    FailureClass,
    JobHistoryServer,
    MetricsAnalyzer,
    RetryPolicy,
    TonYClient,
    YarnLikeBackend,
    classify_exception,
    classify_exit,
    format_failure_report,
    job_spec_from_props,
    make_cluster,
)
from repro.core.failures import (
    diagnose_exception,
    diagnose_heartbeat_timeout,
)


def _job(workers=2, ps=1, attempts=3):
    props = {
        "tony.application.name": "diag",
        "tony.application.max-attempts": str(attempts),
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    }
    if ps:
        props.update({
            "tony.ps.instances": str(ps),
            "tony.ps.memory": "512",
            "tony.ps.node-label": "highmem",
        })
    return job_spec_from_props(props)


# ----------------------------------------------------------------------
# Classification units


def test_classify_user_errors_fatal():
    assert classify_exception(ImportError("no module")) is FailureClass.FATAL_USER
    assert classify_exception(ModuleNotFoundError("x")) is FailureClass.FATAL_USER
    assert classify_exception(AttributeError("x")) is FailureClass.FATAL_USER
    assert classify_exception(NameError("x")) is FailureClass.FATAL_USER
    assert classify_exception(RuntimeError("flaky")) is FailureClass.TRANSIENT
    assert classify_exception(TimeoutError("slow")) is FailureClass.TRANSIENT


def test_classify_exit_codes():
    assert classify_exit(137) is FailureClass.INFRA       # preempted
    assert classify_exit(2) is FailureClass.INFRA         # executor error
    assert classify_exit(143) is FailureClass.TRANSIENT   # AM teardown
    assert classify_exit(1) is FailureClass.TRANSIENT


def test_diagnose_exception_captures_traceback():
    try:
        raise ImportError("No module named 'nonexistent_dep'")
    except ImportError as e:
        d = diagnose_exception("worker:0", e)
    assert d.exception_type == "ImportError"
    assert "nonexistent_dep" in d.message
    assert "Traceback" in d.traceback and "ImportError" in d.traceback
    assert d.classification is FailureClass.FATAL_USER
    assert d.to_dict()["classification"] == "FATAL_USER"


def test_diagnose_heartbeat_timeout_is_transient():
    d = diagnose_heartbeat_timeout("ps:0", 5.0)
    assert d.classification is FailureClass.TRANSIENT
    assert d.exception_type == "HeartbeatTimeout"
    assert "5s" in d.message


# ----------------------------------------------------------------------
# RetryPolicy units (fake clock)


def test_retry_policy_exponential_backoff_capped():
    pol = RetryPolicy(max_attempts=5, base_backoff_s=0.1,
                      backoff_multiplier=2.0, max_backoff_s=0.25)
    assert pol.backoff_for(1) == 0.1
    assert pol.backoff_for(2) == 0.2
    assert pol.backoff_for(3) == 0.25  # capped
    d = pol.decide(1, {FailureClass.TRANSIENT})
    assert d.retry and d.backoff_s == 0.1
    d = pol.decide(2, {FailureClass.INFRA})
    assert d.retry and d.backoff_s == 0.2


def test_retry_policy_fail_fast_and_budget():
    pol = RetryPolicy(max_attempts=3)
    fatal = pol.decide(1, {FailureClass.FATAL_USER, FailureClass.TRANSIENT})
    assert not fatal.retry and "fail-fast" in fatal.reason
    exhausted = pol.decide(3, {FailureClass.TRANSIENT})
    assert not exhausted.retry and "budget" in exhausted.reason


def test_retry_policy_injectable_clock():
    sleeps = []
    pol = RetryPolicy(max_attempts=3, base_backoff_s=0.5).with_clock(sleeps.append)
    pol.sleep(pol.backoff_for(1))
    assert sleeps == [0.5]  # no real time passed


# ----------------------------------------------------------------------
# Integration: fail-fast on FATAL_USER (acceptance criterion)


def _import_error_program(env, ctx):
    ctx.rendezvous(timeout=10)
    if env["TASK_TYPE"] == "worker" and env["TASK_INDEX"] == "0":
        raise ImportError("No module named 'nonexistent_dep'")
    return 0


def test_import_error_fails_fast_with_diagnostics():
    rm = make_cluster()
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        _job(attempts=3), _import_error_program, timeout=60)
    assert res.final_status == "FAILED"
    assert len(res.attempts) == 1          # fail-fast: no retries burned
    d = res.diagnostics["a1/worker:0"]
    assert d.classification is FailureClass.FATAL_USER
    assert d.exception_type == "ImportError"
    assert "nonexistent_dep" in d.message
    assert d.traceback and "ImportError" in d.traceback
    # the event log shows the classified failure and the abandoned retry
    assert rm.events.count("task_failed") >= 1
    assert rm.events.count("attempt_classified") == 1
    assert rm.events.count("retry_scheduled") == 0
    abandoned = rm.events.of_kind("retry_abandoned")
    assert len(abandoned) == 1 and "fail-fast" in abandoned[0].payload["reason"]
    assert "FATAL_USER" in rm.events.of_kind(
        "attempt_classified")[0].payload["classes"]
    # report formatting carries the traceback to the user
    report = format_failure_report(res)
    assert "a1/worker:0" in report and "ImportError" in report


def test_transient_failure_retries_with_backoff_events():
    rm = make_cluster()
    sleeps = []
    pol = RetryPolicy(max_attempts=3, base_backoff_s=0.01).with_clock(sleeps.append)
    calls = {"n": 0}

    def flaky(env, ctx):
        ctx.rendezvous(timeout=10)
        if env["TASK_TYPE"] == "worker" and env["TASK_INDEX"] == "0":
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("injected transient fault")
        return 0

    client = TonYClient(YarnLikeBackend(rm, retry_policy=pol))
    res = client.run_and_wait(_job(), flaky, timeout=60)
    assert res.succeeded and len(res.attempts) == 2
    d = res.diagnostics["a1/worker:0"]
    assert d.classification is FailureClass.TRANSIENT
    assert "injected transient fault" in d.traceback
    sched = rm.events.of_kind("retry_scheduled")
    assert len(sched) == 1
    assert sched[0].payload["backoff_s"] == pol.backoff_for(1)
    assert sleeps == [pol.backoff_for(1)]   # backoff ran on the fake clock


def test_allocation_failure_classified_transient():
    rm = make_cluster(num_gpu_nodes=1, gpus_per_node=1)
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        _job(workers=8, attempts=1), lambda env, ctx: 0, timeout=60)
    assert not res.succeeded
    d = res.diagnostics["a1/__allocation__"]
    assert d.classification is FailureClass.TRANSIENT
    assert d.exception_type == "AllocationError"


# ----------------------------------------------------------------------
# Heartbeat timeout -> classified TRANSIENT failure


def test_heartbeat_timeout_classified_transient():
    rm = make_cluster()
    job = _job(workers=2, ps=0, attempts=1)
    app_id = rm.submit_application(job.name, job.queue)

    def slow(env, ctx):
        ctx.rendezvous(timeout=10)
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline and not ctx.cancel.is_set():
            time.sleep(0.01)
        return 0

    am = ApplicationMaster(rm, app_id, job, slow,
                           retry_policy=RetryPolicy(max_attempts=1))
    am.heartbeat_timeout_s = 0.25
    # drop worker:0's heartbeats (a hung task / lost node)
    real_heartbeat = ApplicationMaster.heartbeat

    def dropping(task_id, progress=None):
        if task_id != "worker:0":
            real_heartbeat(am, task_id, progress)

    am.heartbeat = dropping
    res = am.run()
    assert not res.succeeded
    d = res.diagnostics["a1/worker:0"]
    assert d.exception_type == "HeartbeatTimeout"
    assert d.classification is FailureClass.TRANSIENT
    assert rm.events.count("heartbeat_lost") == 1
    assert "worker:0" in res.attempts[0].failed_tasks


# ----------------------------------------------------------------------
# History server + analyzer surface the attribution


def test_history_summary_answers_why_job_failed():
    rm = make_cluster()
    job = _job(attempts=3)
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(
        job, _import_error_program, timeout=60)
    hist = JobHistoryServer()
    hist.record(job, res)
    s = hist.summary(res.app_id)
    assert s["status"] == "FAILED"
    assert s["diagnostics"]["a1/worker:0"]["exception_type"] == "ImportError"
    assert s["diagnostics"]["a1/worker:0"]["traceback"]
    assert any("FATAL_USER" in r for r in s["failure_reasons"])
    assert "fix the program" in s["retry_advice"]
    kinds = {g.kind for g in MetricsAnalyzer().analyze(job, res)}
    assert "user_error" in kinds
    # the event log's failure timeline is non-empty and ordered
    timeline = rm.events.failure_timeline()
    assert [e.kind for e in timeline][:2] == ["task_failed", "attempt_classified"]
