"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.checkpoint import restore_pytree, save_pytree
from repro.core import (
    ContainerRequest,
    JobSpec,
    Resource,
    ResourceManager,
    TaskSpec,
    Node,
    build_cluster_spec,
    parse_tony_xml,
    to_tony_xml,
)
from repro.core.cluster_spec import TaskAddress
from repro.core.rm import AllocationError
from repro.distributed.sharding import RULES, spec_for

SETTINGS = settings(max_examples=40, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


# ----------------------------------------------------------------------
# RM: resource conservation under arbitrary alloc/release interleavings

ops_strategy = st.lists(
    st.tuples(st.sampled_from(["alloc", "release"]),
              st.integers(0, 3),            # node-label choice / release idx
              st.integers(1, 4000),         # memory
              st.integers(0, 4)),           # gpus
    min_size=1, max_size=60)


@SETTINGS
@given(ops_strategy)
def test_rm_conservation_under_random_ops(ops):
    nodes = [Node("g0", Resource(8000, 64, 4), frozenset({"gpu"})),
             Node("g1", Resource(8000, 64, 4), frozenset({"gpu"})),
             Node("c0", Resource(16000, 64, 0), frozenset({"highmem"}))]
    rm = ResourceManager(nodes)
    app = rm.submit_application("prop", "default")
    live = []
    for kind, sel, mem, gpus in ops:
        if kind == "alloc":
            label = ["gpu", "highmem", None, None][sel]
            try:
                c = rm.allocate(app, ContainerRequest(Resource(mem, 1, gpus), label))
                live.append(c)
                if label:
                    assert label in rm.nodes[c.node_id].labels
            except AllocationError:
                pass
        elif live:
            c = live.pop(sel % len(live))
            rm.release(c.container_id)
        assert rm.invariants_ok()
    for n in rm.nodes.values():
        assert n.used.nonnegative and n.used.fits_in(n.capacity)


# ----------------------------------------------------------------------
# Cluster spec: permutation-invariant, ordered by index

@SETTINGS
@given(st.permutations(list(range(6))), st.integers(1, 4))
def test_cluster_spec_order_invariant(perm, n_ps):
    addrs = ([TaskAddress("worker", i, f"h{i}", 1000 + i) for i in range(6)]
             + [TaskAddress("ps", i, f"p{i}", 2000 + i) for i in range(n_ps)])
    shuffled = [addrs[i] for i in perm] + addrs[6:]
    spec = build_cluster_spec(shuffled)
    assert spec["worker"] == [f"h{i}:{1000+i}" for i in range(6)]
    assert spec["ps"] == [f"p{i}:{2000+i}" for i in range(n_ps)]


# ----------------------------------------------------------------------
# XML round trip for arbitrary job specs

names = st.text(alphabet="abcdefgh", min_size=1, max_size=8)


@SETTINGS
@given(st.dictionaries(
    st.sampled_from(["worker", "ps", "chief", "evaluator"]),
    st.tuples(st.integers(1, 16), st.integers(128, 1 << 20),
              st.integers(1, 64), st.integers(0, 8),
              st.sampled_from([None, "gpu", "highmem"])),
    min_size=1, max_size=4), names)
def test_xml_roundtrip_property(tasks, name):
    spec = JobSpec(name=name, tasks={
        t: TaskSpec(t, inst, Resource(mem, vc, gp), lbl)
        for t, (inst, mem, vc, gp, lbl) in tasks.items()})
    again = parse_tony_xml(to_tony_xml(spec))
    assert set(again.tasks) == set(spec.tasks)
    for t, ts in spec.tasks.items():
        at = again.tasks[t]
        assert (at.instances, at.resource, at.node_label) == \
            (ts.instances, ts.resource, ts.node_label)


# ----------------------------------------------------------------------
# Checkpoint: save/restore is identity for arbitrary nested pytrees

leaf = st.tuples(st.integers(1, 4), st.integers(1, 4)).map(
    lambda s: np.random.default_rng(0).normal(size=s).astype(np.float32))
trees = st.recursive(
    leaf, lambda ch: st.dictionaries(names, ch, min_size=1, max_size=3),
    max_leaves=8)


@SETTINGS
@given(trees, st.integers(0, 10 ** 7))
def test_checkpoint_roundtrip_property(tree, step):
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, d, step)
        back = restore_pytree(jax.tree.map(lambda x: x, tree), d, step)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------------
# Checkpoint commit protocol: arbitrary kill points never expose a partial
# step (latest_step only ever names a fully committed directory)


class _ModuleProxy:
    """A module stand-in with chosen attributes overridden — patches the
    checkpointer module's view only, not numpy/json/os globally."""

    def __init__(self, mod, **overrides):
        self._mod = mod
        self._overrides = overrides

    def __getattr__(self, name):
        if name in self._overrides:
            return self._overrides[name]
        return getattr(self._mod, name)


class _Killed(RuntimeError):
    pass


@SETTINGS
@given(st.integers(0, 3), st.integers(2, 10 ** 6))
def test_checkpoint_kill_point_never_corrupts_latest(kill_op, step):
    """kill_op: 0 = no kill, 1 = during array write, 2 = during COMMIT
    write, 3 = at the atomic rename. The kill leaves all debris in place (a
    hard kill runs no finally). Invariant: latest_step names the new step
    iff every op completed; otherwise the previous checkpoint is intact."""
    import json
    import os
    import shutil
    import tempfile

    import repro.checkpoint.checkpointer as ck

    def killer(*a, **k):
        raise _Killed

    tree1 = {"w": np.ones((3,), np.float32)}
    tree2 = {"w": np.full((3,), 7.0, np.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree1, d, 1)
        saved = (ck.np, ck.json, ck.os, ck.shutil)
        try:
            if kill_op == 1:
                ck.np = _ModuleProxy(np, savez=killer)
            elif kill_op == 2:
                ck.json = _ModuleProxy(json, dump=killer)
            elif kill_op == 3:
                ck.os = _ModuleProxy(os, replace=killer)
            if kill_op:
                ck.shutil = _ModuleProxy(shutil,
                                         rmtree=lambda *a, **k: None)
                with pytest.raises(_Killed):
                    ck.save_pytree(tree2, d, step)
            else:
                ck.save_pytree(tree2, d, step)
        finally:
            ck.np, ck.json, ck.os, ck.shutil = saved
        if kill_op:
            assert ck.latest_step(d) == 1
            assert not ck.is_committed(d, step)
            back = restore_pytree({"w": np.zeros((3,), np.float32)}, d)
            np.testing.assert_array_equal(back["w"], tree1["w"])
        else:
            assert ck.latest_step(d) == step
            back = restore_pytree({"w": np.zeros((3,), np.float32)}, d, step)
            np.testing.assert_array_equal(back["w"], tree2["w"])


# ----------------------------------------------------------------------
# Sharding rules: produced specs always divide the dims they shard

axes_st = st.lists(st.sampled_from(["embed", "mlp", "heads", "kv_heads",
                                    "vocab", "experts", "lru", None]),
                   min_size=1, max_size=4)
dims_st = st.lists(st.sampled_from([1, 2, 8, 16, 24, 32, 64, 100, 256, 4096]),
                   min_size=1, max_size=4)


@SETTINGS
@given(axes_st, dims_st, st.sampled_from(list(RULES)))
def test_sharding_specs_always_divisible(axes, dims, strategy):
    import os
    n = min(len(axes), len(dims))
    axes, dims = tuple(axes[:n]), tuple(dims[:n])

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = spec_for(axes, dims, FakeMesh(), RULES[strategy],
                    max_shardings=1 if strategy == "ps" else None)
    used = []
    for entry, dim in zip(tuple(spec), dims):
        if entry is None:
            continue
        parts = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for p in parts:
            size *= FakeMesh.shape[p]
            used.append(p)
        assert dim % size == 0
    assert len(used) == len(set(used))  # no mesh axis reused in one param
