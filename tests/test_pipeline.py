"""Data pipeline: vectorized synthetic batches stay bit-identical to the
per-sequence reference, file batches never leak memmap backing, and the
prefetching loader is batch-for-batch equivalent to the synchronous path —
including across a checkpoint save/restore.
"""
import numpy as np
import pytest

from repro.data import (
    FileTokenDataset,
    PrefetchingLoader,
    SyntheticLMDataset,
)


def _reference_batch_at(ds: SyntheticLMDataset, step: int) -> dict:
    """The pre-vectorization per-sequence loop, rng draw order preserved."""
    rng = np.random.default_rng((ds.seed, step))
    B, T = ds.batch_size, ds.seq_len
    m_idx = rng.integers(0, len(ds.motifs), size=(B,))
    mlen = ds.motifs.shape[1]
    reps = T // mlen + 2
    rows = [np.tile(ds.motifs[m_idx[i]], reps)[:T + 1] for i in range(B)]
    seqs = np.stack(rows)
    noise_mask = rng.random((B, T + 1)) < ds.noise_prob
    noise = rng.integers(0, ds.vocab_size, size=(B, T + 1))
    seqs = np.where(noise_mask, noise, seqs).astype(np.int32)
    return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


@pytest.mark.parametrize("step", [0, 1, 5, 123])
def test_vectorized_synthetic_batch_matches_reference(step):
    ds = SyntheticLMDataset(8, 37, 211, seed=17)
    got, want = ds.batch_at(step), _reference_batch_at(ds, step)
    for k in ("tokens", "labels"):
        np.testing.assert_array_equal(got[k], want[k])
        assert got[k].dtype == np.int32


def test_file_dataset_batches_are_not_memmap_backed(tmp_path):
    path = str(tmp_path / "corpus.bin")
    tokens = np.arange(4 * 3 * (16 + 1), dtype=np.int32)
    FileTokenDataset.write_corpus(path, tokens)
    ds = FileTokenDataset(path, batch_size=3, seq_len=16)
    for step in range(3):
        batch = ds.next_batch()
        for k, arr in batch.items():
            assert arr.dtype == np.int32
            base = arr
            while base is not None:       # walk the view chain to the owner
                assert not isinstance(base, np.memmap), \
                    f"{k} at step {step} still memmap-backed"
                base = base.base
    # content sanity: step 0 is the first tokens_per_batch slice
    first = ds.batch_at(0)
    chunk = tokens[:3 * 17].reshape(3, 17)
    np.testing.assert_array_equal(first["tokens"], chunk[:, :-1])
    np.testing.assert_array_equal(first["labels"], chunk[:, 1:])


def test_prefetch_matches_sync_sequence():
    sync = SyntheticLMDataset(4, 16, 101, seed=3)
    pre = PrefetchingLoader(SyntheticLMDataset(4, 16, 101, seed=3), depth=3)
    try:
        for _ in range(10):
            a, b = sync.next_batch(), pre.next_batch()
            np.testing.assert_array_equal(a["tokens"], b["tokens"])
            np.testing.assert_array_equal(a["labels"], b["labels"])
    finally:
        pre.close()


def test_prefetch_restore_is_batch_for_batch_identical():
    sync = SyntheticLMDataset(4, 16, 101, seed=3)
    pre = PrefetchingLoader(SyntheticLMDataset(4, 16, 101, seed=3), depth=2)
    try:
        for _ in range(5):
            sync.next_batch(), pre.next_batch()
        saved = pre.state_dict()
        assert saved == sync.state_dict() == {"step": 5}
        # a fresh loader restored from the checkpoint continues exactly
        # where the synchronous iterator would
        pre2 = PrefetchingLoader(SyntheticLMDataset(4, 16, 101, seed=3),
                                 depth=4)
        try:
            pre2.next_batch()            # desync on purpose, then seek back
            pre2.load_state_dict(saved)
            for _ in range(6):
                a, b = sync.next_batch(), pre2.next_batch()
                np.testing.assert_array_equal(a["tokens"], b["tokens"])
        finally:
            pre2.close()
    finally:
        pre.close()


def test_prefetch_step_setter_seeks():
    pre = PrefetchingLoader(SyntheticLMDataset(2, 8, 50, seed=1), depth=2)
    try:
        pre.next_batch()
        assert pre.step == 1
        pre.step = 7                     # programs.py resume path assigns this
        got = pre.next_batch()
        want = SyntheticLMDataset(2, 8, 50, seed=1).batch_at(7)
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
        assert pre.step == 8
    finally:
        pre.close()


def test_prefetch_producer_error_surfaces_on_consumer():
    class Exploding(SyntheticLMDataset):
        def batch_at(self, step):
            if step >= 2:
                raise ValueError("bad shard")
            return super().batch_at(step)

    pre = PrefetchingLoader(Exploding(2, 8, 50, seed=1), depth=1)
    try:
        pre.next_batch(), pre.next_batch()
        with pytest.raises(ValueError, match="bad shard"):
            pre.next_batch()
    finally:
        pre.close()


def test_prefetch_close_stops_production():
    pre = PrefetchingLoader(SyntheticLMDataset(2, 8, 50, seed=1), depth=2)
    pre.close()
    assert not pre._thread.is_alive()
    with pytest.raises(RuntimeError, match="closed"):
        for _ in range(4):               # drain any already-buffered batches
            pre.next_batch()
