"""Elastic gang resize: degrade instead of die.

Units (tier-1): allocate_up_to partial grants, min-instances config
parsing/validation, per-queue blacklist scopes, barrier shrink.

Chaos-marked e2e: the acceptance trajectory — a 4-worker min-2 job on a
cluster where blacklisting leaves room for only 3 launches degraded and
completes; after parole a follow-up attempt regrows to 4; mid-attempt INFRA
losses above the floor shed the member and the attempt continues; partitions
during rendezvous ride out (time-gated) or burn one attempt (step-gated).
All deterministic under CHAOS_SEED=1234 and leak-free.
"""
import os
import threading
import time

import pytest

from repro.core import (
    AllocationError,
    ApplicationMaster,
    ContainerRequest,
    EventLog,
    FailureClass,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    NodeHealthTracker,
    Resource,
    RetryPolicy,
    TaskDiagnostics,
    job_spec_from_props,
    make_cluster,
    to_tony_xml,
)
from repro.core.task_executor import CancellableBarrier

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))

INFRA_DIAG = TaskDiagnostics(task_id="worker:0", exit_status=137,
                             classification=FailureClass.INFRA,
                             message="synthetic infra failure")

WORKER_REQ = ContainerRequest(Resource(1024, 1, 1), "gpu")


def _one_slot_cluster(n=4, events=None, chaos=None, health=None):
    """n gpu nodes that each fit exactly one 1-GPU worker."""
    return make_cluster(num_gpu_nodes=n, num_cpu_nodes=0, gpus_per_node=1,
                        memory_mb=2048, vcores=4, event_log=events,
                        chaos=chaos, health=health)


def _elastic_job(workers=4, min_workers=2, attempts=3):
    return job_spec_from_props({
        "tony.application.name": "elastic",
        "tony.application.max-attempts": str(attempts),
        "tony.worker.instances": str(workers),
        "tony.worker.min-instances": str(min_workers),
        "tony.worker.memory": "1024",
        "tony.worker.vcores": "1",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })


def _gang_program(steps=6, final_rendezvous=True):
    """Every member steps (so per-task chaos can fire on any of them); the
    lead worker drives, others mirror its progress like launch/programs.py."""
    def program(env, ctx):
        task_id = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        attempt = int(ctx.shared.get("attempt", 1))
        if not ctx.rendezvous(timeout=10, exec_id=task_id, attempt=attempt):
            return 3
        if task_id == "worker:0":
            start = int(ctx.shared.get("resume_step", 0))
            try:
                for step in range(start, steps):
                    if ctx.cancel.is_set():
                        return 143
                    ctx.step(task_id, attempt, step)
                    time.sleep(0.005)
                    if (step + 1) % 2 == 0:
                        ctx.shared["ckpt_step"] = step + 1
            finally:
                ctx.shared["done"] = True
        else:
            my_step = -1
            while not ctx.cancel.is_set() and not ctx.shared.get("done"):
                lead = ctx.progress.get("worker:0", -1)
                if my_step < lead:
                    my_step += 1
                    ctx.step(task_id, attempt, my_step)
                else:
                    time.sleep(0.002)
        if final_rendezvous:
            ctx.rendezvous(timeout=5, exec_id=task_id, attempt=attempt)
        return 0

    return program


def _run_am(rm, job, program, max_attempts=3, negotiation_s=0.4,
            sleep=lambda s: None, timeout=45):
    app_id = rm.submit_application(job.name, job.queue)
    am = ApplicationMaster(
        rm, app_id, job, program,
        retry_policy=RetryPolicy(max_attempts=max_attempts).with_clock(sleep))
    am.NEGOTIATION_TIMEOUT_S = negotiation_s
    am.heartbeat_timeout_s = 1.0
    box = {}
    t = threading.Thread(target=lambda: box.update(result=am.run()),
                         daemon=True)
    t.start()
    t.join(timeout)
    assert not t.is_alive(), "AM hung"
    return box["result"]


# ----------------------------------------------------------------------
# allocate_up_to units

def test_allocate_up_to_partial_grant_above_minimum():
    ev = EventLog()
    rm = _one_slot_cluster(3, events=ev)
    app = rm.submit_application("j", "default")
    got = rm.allocate_up_to(app, WORKER_REQ, 4, minimum=2)
    assert len(got) == 3
    assert ev.count("partial_allocation") == 1
    p = ev.of_kind("partial_allocation")[0].payload
    assert (p["granted"], p["requested"], p["minimum"]) == (3, 4, 2)
    assert rm.invariants_ok()
    for c in got:
        rm.release(c.container_id)
    assert not rm.live_containers()


def test_allocate_up_to_below_minimum_releases_everything():
    ev = EventLog()
    rm = _one_slot_cluster(3, events=ev)
    app = rm.submit_application("j", "default")
    with pytest.raises(AllocationError):
        rm.allocate_up_to(app, WORKER_REQ, 6, minimum=4)
    assert not rm.live_containers()
    assert rm.invariants_ok()
    assert ev.count("partial_allocation") == 0


def test_allocate_up_to_full_grant_emits_no_partial_event():
    ev = EventLog()
    rm = _one_slot_cluster(4, events=ev)
    app = rm.submit_application("j", "default")
    got = rm.allocate_up_to(app, WORKER_REQ, 3, minimum=2)
    assert len(got) == 3
    assert ev.count("partial_allocation") == 0


def test_allocate_up_to_chaos_midway_no_leak():
    """FAIL_ALLOCATION mid-gang: below the minimum every straggler container
    is released (satellite: no leaks on partial gang allocation)."""
    ev = EventLog()
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.FAIL_ALLOCATION, after_allocs=2, count=1))
    rm = _one_slot_cluster(4, events=ev,
                           chaos=FaultInjector(plan, events=ev))
    app = rm.submit_application("j", "default")
    with pytest.raises(AllocationError):
        rm.allocate_up_to(app, WORKER_REQ, 4, minimum=3)
    assert not rm.live_containers()
    assert rm.invariants_ok()


# ----------------------------------------------------------------------
# min-instances config units

def test_min_instances_parsing_and_roundtrip():
    job = _elastic_job(workers=4, min_workers=2)
    t = job.tasks["worker"]
    assert t.min_instances == 2 and t.floor == 2 and t.elastic
    xml = to_tony_xml(job)
    again = job_spec_from_props(
        {"tony.worker.instances": "4", "tony.worker.min-instances": "2",
         "tony.application.name": "x"})
    assert again.tasks["worker"].min_instances == 2
    assert "min-instances" in xml


def test_min_instances_defaults_to_rigid():
    job = job_spec_from_props({"tony.application.name": "x",
                               "tony.worker.instances": "4"})
    t = job.tasks["worker"]
    assert t.min_instances is None and t.floor == 4 and not t.elastic


@pytest.mark.parametrize("bad", ["0", "5", "-1"])
def test_min_instances_validation_rejects_out_of_range(bad):
    with pytest.raises(ValueError):
        job_spec_from_props({"tony.application.name": "x",
                             "tony.worker.instances": "4",
                             "tony.worker.min-instances": bad})


# ----------------------------------------------------------------------
# per-queue blacklist scopes (satellite)

def test_blacklist_scopes_are_isolated():
    tr = NodeHealthTracker(threshold=2, parole_s=60.0)
    for _ in range(2):
        tr.record_failure("n0", INFRA_DIAG, scope="prod")
    assert tr.is_blacklisted("n0", "prod")
    assert not tr.is_blacklisted("n0", "dev")
    assert tr.blacklisted(scope="prod") == ["n0"]
    assert tr.blacklisted(scope="dev") == []
    assert tr.blacklisted() == ["n0"]          # union across scopes
    snap = tr.snapshot()
    assert snap["failures"] == {"n0@prod": 2}
    assert snap["blacklisted"] == ["n0@prod"]


def test_blacklist_parole_is_per_scope():
    t = [0.0]
    tr = NodeHealthTracker(threshold=1, parole_s=10.0, clock=lambda: t[0])
    tr.record_failure("n0", INFRA_DIAG, scope="prod")
    tr.record_failure("n0", INFRA_DIAG, scope="dev")
    assert tr.is_blacklisted("n0", "prod") and tr.is_blacklisted("n0", "dev")
    t[0] = 11.0
    # parole in one scope does not touch the other's deadline bookkeeping
    assert not tr.is_blacklisted("n0", "prod")
    assert tr.snapshot()["failures"]["n0@prod"] == 0  # threshold-1
    assert not tr.is_blacklisted("n0", "dev")


def test_rm_strikes_under_one_queue_spare_the_other():
    ev = EventLog()
    rm = make_cluster(num_gpu_nodes=1, num_cpu_nodes=0, gpus_per_node=4,
                      event_log=ev, queues={"prod": 0.5, "dev": 0.5})
    node = next(iter(rm.nodes))
    for _ in range(3):
        rm.report_node_failure(node, INFRA_DIAG, queue="prod")
    app_prod = rm.submit_application("p", "prod")
    app_dev = rm.submit_application("d", "dev")
    with pytest.raises(AllocationError):
        rm.allocate(app_prod, WORKER_REQ)
    c = rm.allocate(app_dev, WORKER_REQ)      # dev placement unaffected
    assert c.node_id == node
    rm.release(c.container_id)
    assert rm.invariants_ok()


# ----------------------------------------------------------------------
# barrier shrink unit

def test_barrier_reduce_releases_current_waiters():
    b = CancellableBarrier(3)
    results = []
    threads = [threading.Thread(
        target=lambda: results.append(b.wait(timeout=5.0)), daemon=True)
        for _ in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.1)
    b.reduce(1)                                # 3 -> 2: both waiters form a gang
    for t in threads:
        t.join(5.0)
    assert results == [True, True]
    assert b.n == 2


# ----------------------------------------------------------------------
# chaos e2e: the acceptance trajectories

@pytest.mark.chaos
def test_degraded_launch_on_blacklist_shrunk_cluster():
    """4-worker min-2 job, 4 one-slot nodes, one pre-blacklisted: the
    attempt launches with 3 workers and completes degraded."""
    ev = EventLog()
    health = NodeHealthTracker(threshold=1, parole_s=3600.0, events=ev)
    rm = _one_slot_cluster(4, events=ev, health=health)
    health.record_failure("gpu-node-0", INFRA_DIAG)
    res = _run_am(rm, _elastic_job(), _gang_program())

    assert res.succeeded
    assert len(res.attempts) == 1
    assert res.resized_attempts == {1: {"worker": 3}}
    assert ev.count("gang_resized") == 1
    assert ev.of_kind("gang_resized")[0].payload["reason"] == \
        "allocation_shortfall"
    assert ev.count("attempt_degraded") == 1
    d = ev.of_kind("attempt_degraded")[0].payload
    assert (d["world_size"], d["target_world"]) == (3, 4)
    assert not rm.live_containers()
    assert rm.invariants_ok()


@pytest.mark.chaos
def test_regrow_to_full_gang_after_parole():
    """Attempt 1 runs degraded (one node blacklisted); a chaos kill forces a
    retry, the retry backoff outlives the parole window, and attempt 2
    regrows to the full 4-worker gang."""
    t = [0.0]
    ev = EventLog()
    health = NodeHealthTracker(threshold=1, parole_s=5.0,
                               clock=lambda: t[0], events=ev)
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.KILL_TASK, task="worker:0", attempt=1, at_step=3))
    rm = _one_slot_cluster(4, events=ev,
                           chaos=FaultInjector(plan, events=ev),
                           health=health)
    health.record_failure("gpu-node-0", INFRA_DIAG)

    def sleep_advances_parole(_s):
        t[0] += 10.0                    # retry backoff outlives parole

    res = _run_am(rm, _elastic_job(), _gang_program(),
                  sleep=sleep_advances_parole)

    assert res.succeeded
    assert len(res.attempts) == 2
    assert res.attempts[0].degraded and not res.attempts[1].degraded
    assert res.resized_attempts == {1: {"worker": 3}}
    assert res.attempts[1].task_counts == {"worker": 4}
    assert ev.count("node_paroled") == 1
    assert ev.count("gang_regrown") == 1
    g = ev.of_kind("gang_regrown")[0].payload
    assert (g["from_world"], g["world_size"]) == (3, 4)
    # checkpoint recovery stayed intact across the degraded attempt
    assert res.attempts[1].resume_step == 2
    assert not rm.live_containers()
    assert rm.invariants_ok()


@pytest.mark.chaos
def test_mid_attempt_infra_loss_sheds_member_and_continues():
    """An OOM (INFRA) on a non-chief elastic worker above the floor removes
    it from the gang; the attempt finishes degraded instead of retrying."""
    ev = EventLog()
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.OOM, task="worker:1", at_step=2))
    rm = _one_slot_cluster(4, events=ev, chaos=FaultInjector(plan, events=ev))
    res = _run_am(rm, _elastic_job(), _gang_program())

    assert res.succeeded
    assert len(res.attempts) == 1
    rep = res.attempts[0]
    assert rep.shed_tasks == ["worker:1"]
    assert rep.task_counts == {"worker": 4}
    assert rep.final_counts() == {"worker": 3}
    assert res.resized_attempts == {1: {"worker": 3}}
    resized = ev.of_kind("gang_resized")
    assert len(resized) == 1 and resized[0].payload["reason"] == "infra_loss"
    # the shed worker's node was charged despite the gang's success
    assert rm.health.snapshot()["failures"]
    assert not rm.live_containers()
    assert rm.invariants_ok()


@pytest.mark.chaos
def test_shed_never_drops_below_floor():
    """First INFRA loss sheds down to the floor; a second one below the
    floor tears the attempt down instead. The retry (faults spent) succeeds
    with the full gang."""
    ev = EventLog()
    plan = (FaultPlan(seed=CHAOS_SEED)
            .add(FaultSpec(FaultKind.OOM, task="worker:1", at_step=1))
            .add(FaultSpec(FaultKind.OOM, task="worker:2", at_step=5)))
    rm = _one_slot_cluster(3, events=ev, chaos=FaultInjector(plan, events=ev))
    res = _run_am(rm, _elastic_job(workers=3, min_workers=2), _gang_program())

    assert res.succeeded
    assert len(res.attempts) == 2
    first = res.attempts[0]
    assert first.shed_tasks == ["worker:1"]     # 3 -> 2: at the floor
    assert "worker:2" in first.failed_tasks     # 2 -> 1 would breach it
    assert ev.count("gang_resized") == 1
    assert not res.attempts[1].shed_tasks
    assert res.attempts[1].task_counts == {"worker": 3}
    assert not rm.live_containers()
    assert rm.invariants_ok()


@pytest.mark.chaos
def test_partition_during_rendezvous_rides_out():
    """A time-gated partition blocks one endpoint's rendezvous for its
    window; the gang forms afterwards and the job completes in one attempt."""
    ev = EventLog()
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.PARTITION, src="worker:1", dst="worker:0",
                  attempt=1, after_s=0.0, duration_s=0.3))
    rm = _one_slot_cluster(4, events=ev, chaos=FaultInjector(plan, events=ev))
    res = _run_am(rm, _elastic_job(), _gang_program())

    assert res.succeeded
    assert len(res.attempts) == 1
    fired = [e for e in ev.of_kind("chaos_injected")
             if e.payload.get("fault") == "partition"]
    # a time-gated partition affects BOTH endpoints; whichever one's hook
    # runs first emits the event, but the pair itself is deterministic
    assert fired and fired[0].payload["task"] in ("worker:0", "worker:1")
    assert (fired[0].payload["src"], fired[0].payload["dst"]) == \
        ("worker:1", "worker:0")
    assert not rm.live_containers()
    assert rm.invariants_ok()


@pytest.mark.chaos
def test_step_gated_partition_burns_one_attempt():
    """A step-gated partition raises ChaosPartition in the src task: the
    attempt dies TRANSIENT and the retry succeeds."""
    ev = EventLog()
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.PARTITION, src="worker:0", dst="worker:2",
                  attempt=1, at_step=2))
    rm = _one_slot_cluster(4, events=ev, chaos=FaultInjector(plan, events=ev))
    res = _run_am(rm, _elastic_job(), _gang_program())

    assert res.succeeded
    assert len(res.attempts) == 2
    diag = res.attempts[0].diagnostics["worker:0"]
    assert diag.exception_type == "ChaosPartition"
    assert diag.classification is FailureClass.TRANSIENT
    # a partition must never poison the blacklist
    assert ev.count("node_blacklisted") == 0
    assert not rm.live_containers()
    assert rm.invariants_ok()


@pytest.mark.chaos
def test_elastic_trajectory_deterministic_for_fixed_seed():
    def run_once():
        ev = EventLog()
        health = NodeHealthTracker(threshold=1, parole_s=3600.0, events=ev)
        rm = _one_slot_cluster(4, events=ev, health=health)
        health.record_failure("gpu-node-0", INFRA_DIAG)
        res = _run_am(rm, _elastic_job(), _gang_program())
        return (res.final_status, len(res.attempts),
                {a: sorted(c.items())
                 for a, c in res.resized_attempts.items()},
                [e.kind for e in ev.failure_timeline()
                 if e.kind in ("gang_resized", "attempt_degraded",
                               "gang_regrown", "partial_allocation")])

    assert run_once() == run_once()


@pytest.mark.chaos
def test_fail_allocation_during_elastic_negotiation_is_leak_free():
    """FAIL_ALLOCATION chaos mid-negotiation: whether the AM rides it out,
    downsizes, or fails the attempt, nothing leaks (satellite)."""
    ev = EventLog()
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.FAIL_ALLOCATION, after_allocs=2, count=2))
    rm = _one_slot_cluster(4, events=ev, chaos=FaultInjector(plan, events=ev))
    res = _run_am(rm, _elastic_job(), _gang_program())

    assert res.succeeded                       # chaos burns out, gang forms
    assert not rm.live_containers()
    assert rm.invariants_ok()
    assert ev.count("chaos_injected") >= 1
