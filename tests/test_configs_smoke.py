"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward + one train step on CPU; output shapes and
finiteness asserted. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.distributed.steps import init_train_state, make_train_fn
from repro.launch.mesh import make_local_mesh, set_mesh
from repro.models import model as M

EXPECTED = {
    "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                              num_kv_heads=1, d_ff=7680, vocab_size=256_000),
    "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                 num_kv_heads=8, d_ff=28_672, vocab_size=128_256),
    "llama3-405b": dict(num_layers=126, d_model=16_384, num_heads=128,
                        num_kv_heads=8, d_ff=53_248, vocab_size=128_256),
    "llama4-maverick-400b-a17b": dict(num_layers=48, d_model=5120, num_heads=40,
                                      num_kv_heads=8, d_ff=8192,
                                      vocab_size=202_048, num_experts=128,
                                      experts_per_token=1),
    "rwkv6-3b": dict(num_layers=32, d_model=2560, d_ff=8960, vocab_size=65_536),
    "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120, num_heads=40,
                                  num_kv_heads=8, d_ff=8192, vocab_size=202_048,
                                  num_experts=16, experts_per_token=1),
    "deepseek-coder-33b": dict(num_layers=62, d_model=7168, num_heads=56,
                               num_kv_heads=8, d_ff=19_200, vocab_size=32_256),
    "whisper-base": dict(num_layers=6, encoder_layers=6, d_model=512,
                         num_heads=8, d_ff=2048, vocab_size=51_865),
    "qwen3-1.7b": dict(num_layers=28, d_model=2048, num_heads=16,
                       num_kv_heads=8, d_ff=6144, vocab_size=151_936,
                       use_qk_norm=True),
    "llama3.2-3b": dict(num_layers=28, d_model=3072, num_heads=24,
                        num_kv_heads=8, d_ff=8192, vocab_size=128_256),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    for field, want in EXPECTED[arch].items():
        assert getattr(cfg, field) == want, (arch, field)
    assert cfg.source, "every config must cite its source"


def _smoke_batch(cfg, rng, B=2, T=16):
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.num_media_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    elif cfg.uses_media:
        batch["media"] = jax.random.normal(
            rng, (B, cfg.num_media_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch, rng):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    assert len(cfg.layer_defs()) == cfg.num_layers
    params = M.init_params(cfg, rng)
    batch = _smoke_batch(cfg, rng)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    mesh = make_local_mesh()
    shape = ShapeConfig("smoke", 16, 2, "train")
    with set_mesh(mesh):
        fn, _ = make_train_fn(cfg, mesh, "fsdp_tp", shape=shape)
        state = init_train_state(cfg, rng)
        step0 = int(state["step"])
        state, metrics = fn(state, _smoke_batch(cfg, rng))
        assert int(state["step"]) == step0 + 1
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        for leaf in jax.tree.leaves(state["params"]):
            assert bool(jnp.isfinite(leaf).all())


def test_input_shapes_assignment():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32_768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_long_context_skip_is_whisper_only():
    skips = [a for a in ARCH_IDS if a != "tony-paper-mlp"
             and not get_config(a).supports_long_context]
    assert skips == ["whisper-base"]
