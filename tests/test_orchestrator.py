"""TonY orchestrator unit + integration tests: RM scheduling, XML config,
client/AM lifecycle, fault tolerance, workflow DAG, history/metrics."""
import threading
import time

import pytest

from repro.core import (
    AllocationError,
    ContainerRequest,
    JobHistoryServer,
    JobSpec,
    MetricsAnalyzer,
    Node,
    Resource,
    ResourceManager,
    TaskSpec,
    TonYClient,
    Workflow,
    YarnLikeBackend,
    build_cluster_spec,
    job_spec_from_props,
    make_cluster,
    parse_tony_xml,
    task_env,
    to_tony_xml,
)
from repro.core.cluster_spec import TaskAddress


# ----------------------------------------------------------------------
# ResourceManager


def test_rm_allocates_on_labelled_nodes():
    rm = make_cluster(num_gpu_nodes=1, num_cpu_nodes=1, gpus_per_node=4)
    app = rm.submit_application("j", "default")
    c = rm.allocate(app, ContainerRequest(Resource(1024, 1, 2), "gpu"))
    assert c.node_id.startswith("gpu-node")
    with pytest.raises(AllocationError):
        rm.allocate(app, ContainerRequest(Resource(1024, 1, 1), "highmem"))
    assert rm.invariants_ok()


def test_rm_respects_node_capacity():
    rm = ResourceManager([Node("n0", Resource(4096, 4, 2), frozenset({"gpu"}))])
    app = rm.submit_application("j", "default")
    rm.allocate(app, ContainerRequest(Resource(2048, 2, 1)))
    rm.allocate(app, ContainerRequest(Resource(2048, 2, 1)))
    with pytest.raises(AllocationError):
        rm.allocate(app, ContainerRequest(Resource(1, 1, 0)))
    assert rm.invariants_ok()


def test_rm_queue_capacity_enforced():
    rm = ResourceManager(
        [Node("n0", Resource(10_000, 100, 0))],
        queues={"prod": 0.8, "adhoc": 0.2})
    a1 = rm.submit_application("p", "prod")
    a2 = rm.submit_application("q", "adhoc")
    rm.allocate(a2, ContainerRequest(Resource(2000, 10, 0)))
    with pytest.raises(AllocationError):  # adhoc over its 20% share
        rm.allocate(a2, ContainerRequest(Resource(100, 1, 0)))
    rm.allocate(a1, ContainerRequest(Resource(7000, 10, 0)))  # prod fits
    assert rm.invariants_ok()


def test_rm_release_returns_resources():
    rm = make_cluster(num_gpu_nodes=1, num_cpu_nodes=0, gpus_per_node=2)
    app = rm.submit_application("j", "default")
    c1 = rm.allocate(app, ContainerRequest(Resource(1024, 1, 2)))
    with pytest.raises(AllocationError):
        rm.allocate(app, ContainerRequest(Resource(1024, 1, 1)))
    rm.release(c1.container_id)
    rm.allocate(app, ContainerRequest(Resource(1024, 1, 2)))
    assert rm.invariants_ok()


def test_allocate_many_rolls_back_on_failure():
    rm = make_cluster(num_gpu_nodes=1, num_cpu_nodes=0, gpus_per_node=4)
    app = rm.submit_application("j", "default")
    with pytest.raises(AllocationError):
        rm.allocate_many(app, ContainerRequest(Resource(1024, 1, 1), "gpu"), 9)
    assert not rm.live_containers()
    assert rm.invariants_ok()


# ----------------------------------------------------------------------
# Config / cluster spec


def test_xml_round_trip():
    spec = JobSpec(
        name="my-job",
        tasks={"worker": TaskSpec("worker", 4, Resource(8192, 4, 1), "gpu"),
               "ps": TaskSpec("ps", 2, Resource(4096, 2, 0), None)},
        queue="prod", args={"lr": "0.1"})
    again = parse_tony_xml(to_tony_xml(spec))
    assert again.tasks["worker"].instances == 4
    assert again.tasks["worker"].resource.gpus == 1
    assert again.tasks["worker"].node_label == "gpu"
    assert again.tasks["ps"].resource.memory_mb == 4096
    assert again.args == {"lr": "0.1"}
    assert again.queue == "prod"


def test_xml_requires_tasks():
    with pytest.raises(ValueError):
        parse_tony_xml("<configuration></configuration>")


def test_cluster_spec_ordering_and_env():
    addrs = [TaskAddress("worker", 1, "h1", 2), TaskAddress("worker", 0, "h0", 1),
             TaskAddress("ps", 0, "h2", 3)]
    spec = build_cluster_spec(addrs)
    assert spec == {"ps": ["h2:3"], "worker": ["h0:1", "h1:2"]}
    env = task_env(spec, "worker", 1, {"lr": "0.1"})
    assert env["TASK_TYPE"] == "worker" and env["TASK_INDEX"] == "1"
    assert env["WORLD_SIZE"] == "3"
    assert env["JOB_ARG_LR"] == "0.1"
    assert '"worker"' in env["TF_CONFIG"]


# ----------------------------------------------------------------------
# Client + AM lifecycle (fast dummy programs, no JAX)


def _ok_program(env, ctx):
    ctx.rendezvous(timeout=10)
    ctx.shared[f"metrics:{env['TASK_TYPE']}:{env['TASK_INDEX']}"] = {
        "peak_memory_mb": 100.0}
    return 0


def _job(workers=2, ps=1, attempts=3):
    return job_spec_from_props({
        "tony.application.name": "t",
        "tony.application.max-attempts": str(attempts),
        "tony.worker.instances": str(workers),
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
        "tony.ps.instances": str(ps),
        "tony.ps.memory": "512",
        "tony.ps.node-label": "highmem",
    })


def test_job_lifecycle_success():
    rm = make_cluster()
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(_job(), _ok_program,
                                                       timeout=60)
    assert res.succeeded and len(res.attempts) == 1
    assert res.ui_url and res.ui_url.startswith("http://")
    assert rm.app_state(res.app_id) == "FINISHED"
    assert not rm.live_containers()
    assert rm.invariants_ok()
    # every task registered exactly once and exited 0
    a = res.attempts[0]
    assert set(a.exit_statuses) == {"worker:0", "worker:1", "ps:0"}
    assert all(v == 0 for v in a.exit_statuses.values())
    assert a.cluster_spec is not None and len(a.cluster_spec["worker"]) == 2


def test_job_relaunch_on_transient_failure():
    rm = make_cluster()
    calls = {"n": 0}

    def flaky(env, ctx):
        ctx.rendezvous(timeout=10)
        if env["TASK_TYPE"] == "worker" and env["TASK_INDEX"] == "0":
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient")
        return 0

    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(_job(), flaky, timeout=60)
    assert res.succeeded and len(res.attempts) == 2
    assert "worker:0" in res.attempts[0].failed_tasks
    assert rm.invariants_ok()


def test_job_fails_after_max_attempts():
    rm = make_cluster()

    def always_fail(env, ctx):
        ctx.rendezvous(timeout=10)
        return 1 if env["TASK_TYPE"] == "worker" else 0

    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(_job(attempts=2),
                                                       always_fail, timeout=60)
    assert not res.succeeded and len(res.attempts) == 2
    assert rm.app_state(res.app_id) == "FAILED"
    assert not rm.live_containers()


def test_job_allocation_failure_is_reported():
    rm = make_cluster(num_gpu_nodes=1, gpus_per_node=1)  # can't fit 2 GPU workers
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(_job(workers=8),
                                                       _ok_program, timeout=60)
    assert not res.succeeded
    assert res.attempts[0].failed_tasks == ["__allocation__"]
    assert rm.invariants_ok()


def test_heterogeneous_allocation_places_by_label():
    rm = make_cluster()
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(_job(), _ok_program,
                                                       timeout=60)
    nodes = {e.payload["node"]: e.payload for e in
             rm.events.of_kind("container_allocated")}
    gpu_allocs = [p for p in nodes.values() if p["gpus"] > 0]
    cpu_allocs = [p for p in nodes.values() if p["gpus"] == 0]
    assert all(p["node"].startswith("gpu-node") for p in gpu_allocs)
    assert all(p["node"].startswith("cpu-node") for p in cpu_allocs)
    assert res.succeeded


def test_metrics_analyzer_suggests_memory_reduction():
    rm = make_cluster()
    job = _job()
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(job, _ok_program,
                                                       timeout=60)
    hist = JobHistoryServer()
    hist.record(job, res)
    assert hist.summary(res.app_id)["status"] == "SUCCEEDED"
    kinds = {s.kind for s in MetricsAnalyzer().analyze(job, res)}
    assert "memory_overprovisioned" in kinds  # 100MB used vs 1024MB asked


# ----------------------------------------------------------------------
# Workflow (Azkaban plugin analogue)


def test_workflow_runs_tony_job_in_dag():
    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    order = []
    wf = Workflow("pipeline")
    wf.add_command("preprocess", lambda ctx: order.append("pre"))
    wf.add_tony_job("train", client, _job(), _ok_program, deps=("preprocess",))
    wf.add_command("deploy", lambda ctx: order.append("deploy"),
                   deps=("train",))
    results = wf.execute()
    assert all(r.status == "SUCCEEDED" for r in results.values())
    assert order == ["pre", "deploy"]


def test_workflow_skips_dependents_on_failure():
    wf = Workflow("w")
    wf.add_command("a", lambda ctx: (_ for _ in ()).throw(RuntimeError("x")))
    wf.add_command("b", lambda ctx: 1, deps=("a",))
    wf.add_command("c", lambda ctx: 2)
    res = wf.execute()
    assert res["a"].status == "FAILED"
    assert res["b"].status == "SKIPPED"
    assert res["c"].status == "SUCCEEDED"


def test_workflow_rejects_cycles():
    wf = Workflow("w")
    wf.add_command("a", lambda ctx: 1, deps=("b",))
    wf.add_command("b", lambda ctx: 1, deps=("a",))
    with pytest.raises(ValueError, match="cycle"):
        wf.execute()


def test_workflow_parallel_where_independent():
    wf = Workflow("w")
    t0 = time.monotonic()
    wf.add_command("a", lambda ctx: time.sleep(0.2))
    wf.add_command("b", lambda ctx: time.sleep(0.2))
    wf.execute()
    assert time.monotonic() - t0 < 0.38  # ran concurrently


def test_negotiation_waits_for_contended_resources():
    """A gang that doesn't fit NOW succeeds once a competing job releases
    (paper §1: resource contention; AM backoff instead of failing)."""
    rm = make_cluster(num_gpu_nodes=1, num_cpu_nodes=0, gpus_per_node=2)
    app_other = rm.submit_application("hog", "default")
    hogs = [rm.allocate(app_other, ContainerRequest(Resource(1024, 1, 1), "gpu"))
            for _ in range(2)]

    def release_later():
        time.sleep(0.3)
        for c in hogs:
            rm.release(c.container_id)

    threading.Thread(target=release_later, daemon=True).start()
    job = job_spec_from_props({
        "tony.application.name": "waiter",
        "tony.worker.instances": "2",
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })
    res = TonYClient(YarnLikeBackend(rm)).run_and_wait(job, _ok_program,
                                                       timeout=60)
    assert res.succeeded and len(res.attempts) == 1
    assert rm.events.count("negotiation_waiting") == 1
    assert rm.events.count("negotiation_unblocked") == 1
    assert rm.invariants_ok()


def test_rm_elastic_preemption_mechanics():
    """Elastic queues borrow idle capacity; preemption reclaims it."""
    rm = ResourceManager(
        [Node("n0", Resource(10_000, 100, 0))],
        queues={"prod": 0.8, "adhoc": 0.2}, elastic=True)
    a_hog = rm.submit_application("hog", "adhoc")
    hogs = [rm.allocate(a_hog, ContainerRequest(Resource(4000, 10, 0)))
            for _ in range(2)]  # 8000 MB on a 20% (2000 MB) share: over-share
    assert rm.queue_over_share("adhoc")
    a_prod = rm.submit_application("p", "prod")
    with pytest.raises(AllocationError):
        rm.allocate(a_prod, ContainerRequest(Resource(6000, 10, 0)))
    n = rm.try_preempt_for(a_prod, ContainerRequest(Resource(6000, 10, 0)))
    assert n >= 1
    assert rm.events.count("container_preempted") == n
    rm.allocate(a_prod, ContainerRequest(Resource(6000, 10, 0)))  # now fits
    assert rm.invariants_ok()
    del hogs


def test_e2e_preemption_triggers_victim_relaunch():
    """A prod job preempts an over-share adhoc job; the victim's executor
    observes the PREEMPTED container and its AM relaunches the attempt."""
    rm = ResourceManager(
        [Node(f"n{i}", Resource(4096, 8, 0)) for i in range(2)],
        queues={"prod": 0.75, "adhoc": 0.25}, elastic=True)
    client = TonYClient(YarnLikeBackend(rm))

    release = threading.Event()

    def hog_program(env, ctx):
        ctx.rendezvous(timeout=10)
        deadline = time.monotonic() + 20.0
        while not release.is_set() and not ctx.cancel.is_set() \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        return 0

    hog_job = job_spec_from_props({
        "tony.application.name": "hog",
        "tony.yarn.queue": "adhoc",
        "tony.application.max-attempts": "10",  # survives repeated preemption
        "tony.worker.instances": "2",
        "tony.worker.memory": "3000",   # 6000 MB total on a 2048 MB share
        "tony.worker.vcores": "1",
    })
    hog_handle = client.submit(hog_job, hog_program)
    while rm.events.count("cluster_spec_built") < 1:
        time.sleep(0.01)
    assert rm.queue_over_share("adhoc")

    prod_job = job_spec_from_props({
        "tony.application.name": "urgent",
        "tony.yarn.queue": "prod",
        "tony.worker.instances": "2",
        "tony.worker.memory": "2500",
        "tony.worker.vcores": "1",
    })
    prod_res = client.run_and_wait(prod_job, _ok_program, timeout=60)
    assert prod_res.succeeded
    assert rm.events.count("container_preempted") >= 1

    release.set()  # let the (relaunched) hog attempt finish
    hog_res = hog_handle.wait(timeout=60)
    assert hog_res.succeeded
    assert len(hog_res.attempts) >= 2          # attempt 1 was preempted
    assert any("worker" in t for t in hog_res.attempts[0].failed_tasks)
    assert rm.invariants_ok()
