"""Workflow integration (paper §2.1): distributed training as one node of a
larger Azkaban-style DAG — preprocess -> train (TonY job) -> evaluate.

    PYTHONPATH=src python examples/workflow_pipeline.py
"""
import os
import tempfile

import numpy as np

from repro.configs import get_config
from repro.core import TonYClient, Workflow, YarnLikeBackend, job_spec_from_props, make_cluster
from repro.data import FileTokenDataset
from repro.launch.programs import make_train_program


def main() -> None:
    workdir = tempfile.mkdtemp(prefix="pipeline-")
    corpus = os.path.join(workdir, "corpus.bin")
    cfg = get_config("tony-paper-mlp").replace(vocab_size=512)

    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    job = job_spec_from_props({
        "tony.application.name": "wf-train",
        "tony.worker.instances": "2",
        "tony.worker.memory": "4096",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
        "tony.ps.instances": "1",
        "tony.ps.memory": "2048",
        "tony.ps.node-label": "highmem",
    })

    losses = []

    def preprocess(ctx):
        rng = np.random.default_rng(0)
        motif = rng.integers(0, cfg.vocab_size, size=16)
        tokens = np.tile(motif, 4000)
        FileTokenDataset.write_corpus(corpus, tokens)
        ctx["corpus"] = corpus
        return len(tokens)

    def evaluate(ctx):
        assert losses, "training produced no steps"
        ctx["final_loss"] = losses[-1]
        return losses[-1]

    wf = Workflow("ml-pipeline")
    wf.add_command("preprocess", preprocess)
    wf.add_tony_job(
        "train", client, job,
        make_train_program(cfg, steps=25, batch_size=8, seq_len=32,
                           ckpt_dir=os.path.join(workdir, "ckpt"),
                           data_kind="file", data_path=corpus,
                           on_step=lambda s, m: losses.append(m["loss"])),
        deps=("preprocess",))
    wf.add_command("evaluate", evaluate, deps=("train",))

    results = wf.execute()
    for name in ("preprocess", "train", "evaluate"):
        print(f"{name:12s}: {results[name].status}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} (file-backed corpus)")
    assert all(r.status == "SUCCEEDED" for r in results.values())
    assert losses[-1] < losses[0]
    print("OK")


if __name__ == "__main__":
    main()
