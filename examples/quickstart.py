"""Quickstart: the paper's core user journey in ~40 lines.

Describe a distributed training job in tony.xml (worker/ps task types,
heterogeneous resources), submit through the TonY client, and get back the
UI URL, task logs and resource-tuning suggestions.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs import get_config
from repro.core import (
    JobHistoryServer,
    MetricsAnalyzer,
    TonYClient,
    YarnLikeBackend,
    make_cluster,
    parse_tony_xml,
)
from repro.launch.programs import make_train_program

TONY_XML = """
<configuration>
  <property><name>tony.application.name</name><value>quickstart</value></property>
  <property><name>tony.worker.instances</name><value>2</value></property>
  <property><name>tony.worker.memory</name><value>8192</value></property>
  <property><name>tony.worker.gpus</name><value>1</value></property>
  <property><name>tony.worker.node-label</name><value>gpu</value></property>
  <property><name>tony.ps.instances</name><value>1</value></property>
  <property><name>tony.ps.memory</name><value>4096</value></property>
  <property><name>tony.ps.node-label</name><value>highmem</value></property>
</configuration>
"""


def main() -> None:
    # 1. a simulated heterogeneous cluster (the pluggable "YARN")
    rm = make_cluster(num_gpu_nodes=2, num_cpu_nodes=2, gpus_per_node=4)
    client = TonYClient(YarnLikeBackend(rm))

    # 2. the job: paper-native small dense model, real JAX training loop
    cfg = get_config("tony-paper-mlp")
    job = parse_tony_xml(TONY_XML)
    losses = []
    program = make_train_program(
        cfg, steps=30, batch_size=8, seq_len=64,
        ckpt_dir=tempfile.mkdtemp(prefix="quickstart-"),
        on_step=lambda s, m: losses.append(m["loss"]))

    # 3. submit and wait
    result = client.run_and_wait(job, program)

    # 4. everything the paper says you get back in one place
    history = JobHistoryServer()
    history.record(job, result)
    print("status       :", result.final_status)
    print("ui url       :", result.ui_url)
    print("task logs    :", sorted(result.task_logs))
    print("loss         :", f"{losses[0]:.3f} -> {losses[-1]:.3f}")
    for s in MetricsAnalyzer().analyze(job, result):
        print("suggestion   :", s.message)
    assert result.succeeded and losses[-1] < losses[0]
    print("OK")


if __name__ == "__main__":
    main()
