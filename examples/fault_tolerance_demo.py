"""Fault-tolerance demo (paper §2.2): a worker dies mid-training; the AM
classifies the failure (TRANSIENT), schedules a retry with backoff, tears the
attempt down, negotiates fresh containers, broadcasts a NEW cluster spec, and
the relaunched job restores from the last checkpoint.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import tempfile

from repro.configs import get_config
from repro.core import (
    FailureClass,
    TonYClient,
    YarnLikeBackend,
    job_spec_from_props,
    make_cluster,
)
from repro.launch.programs import make_train_program


def main() -> None:
    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    cfg = get_config("tony-paper-mlp").replace(d_model=128, num_heads=2,
                                               num_kv_heads=2, d_ff=256,
                                               vocab_size=512)
    job = job_spec_from_props({
        "tony.application.name": "fault-demo",
        "tony.worker.instances": "2",
        "tony.worker.memory": "4096",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })

    trace = []
    program = make_train_program(
        cfg, steps=24, batch_size=8, seq_len=32,
        ckpt_dir=tempfile.mkdtemp(prefix="fault-demo-"), ckpt_every=6,
        fail_at=(1, 15),  # crash on attempt 1 at step 15 (ckpt exists at 12)
        on_step=lambda s, m: trace.append((s, round(m["loss"], 3))))

    result = client.run_and_wait(job, program)

    print("attempts:", len(result.attempts))
    print("attempt 1 failed tasks:", result.attempts[0].failed_tasks)

    # the diagnostics subsystem attributed the crash before retrying
    diag = result.diagnostics["a1/worker:0"]
    print(f"attempt 1 diagnosis: [{diag.classification.value}] "
          f"{diag.exception_type}: {diag.message}")
    assert diag.classification is FailureClass.TRANSIENT
    assert "injected transient failure" in diag.traceback
    retry_ev = rm.events.of_kind("retry_scheduled")[0]
    print(f"retry scheduled with backoff_s={retry_ev.payload['backoff_s']}")

    steps = [s for s, _ in trace]
    resume = next(s for i, s in enumerate(steps[1:], 1) if s <= steps[i - 1])
    print(f"attempt 2 resumed from checkpoint at step {resume} (not step 0)")
    print("loss trace around the failure:",
          [t for t in trace if 10 <= t[0] <= 18])
    print("containers allocated total:",
          rm.events.count("container_allocated"), "(2 per attempt)")
    assert result.succeeded and len(result.attempts) == 2 and resume == 12
    print("failure timeline kinds:",
          [e.kind for e in rm.events.failure_timeline()])
    print("OK")


if __name__ == "__main__":
    main()
