"""Fault-tolerance demo (paper §2.2), driven by the chaos harness.

Act 1 — crash recovery: a seeded FaultPlan OOMs the chief worker at step 5
on its first two attempts. The AM classifies each failure (INFRA, oom),
schedules retries with backoff, resumes every relaunch from the last
committed checkpoint (step 3, not step 0), and after the second OOM on the
same host the RM blacklists that node — attempt 3 is placed elsewhere and
trains to completion.

Act 2 — speculative execution: a SLOW_STEP fault makes one worker a
straggler (slow, not dead — crash recovery never triggers). The AM spots it
lagging the gang median in heartbeat progress, launches a backup copy on a
different node, the backup wins the race, and the slow original is torn
down as a TRANSIENT loser without ever striking its node.

Act 3 — elastic shrink and regrow: a blacklisted node leaves a 4-worker
(min-instances=2) job only 3 slots, so instead of burning the negotiation
window and dying, the AM downsizes the gang to 3 and launches the attempt
degraded. A chaos kill forces a retry whose backoff outlives the bad node's
parole — and because every attempt asks for the full gang first, attempt 2
regrows to 4 workers automatically.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
    CHAOS_SEED=99 PYTHONPATH=src python examples/fault_tolerance_demo.py

See ROADMAP.md ("Testing with the chaos harness") for the recipe these acts
follow: seed a plan, run the job, assert on the event trail.
"""
import os
import tempfile
import time

from repro.configs import get_config
from repro.core import (
    EXIT_SPECULATION_LOST,
    ApplicationMaster,
    EventLog,
    FailureClass,
    FaultInjector,
    FaultKind,
    FaultPlan,
    FaultSpec,
    JobHistoryServer,
    MetricsAnalyzer,
    NodeHealthTracker,
    RetryPolicy,
    SpeculationPolicy,
    TaskDiagnostics,
    TonYClient,
    YarnLikeBackend,
    job_spec_from_props,
    make_cluster,
)
from repro.launch.programs import make_train_program

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))


def speculation_act() -> None:
    """Act 2: injected straggler -> detection -> backup wins the race."""
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.SLOW_STEP, task="worker:1", at_step=2,
                  delay_s=0.08))
    events = EventLog()
    rm = make_cluster(event_log=events,
                      chaos=FaultInjector(plan, events=events))
    policy = SpeculationPolicy(enabled=True, slowdown_factor=2.0,
                               patience=3, min_progress=4)
    job = job_spec_from_props({
        "tony.application.name": "speculation-demo",
        "tony.worker.instances": "3",
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })

    def gang_program(env, ctx):
        tid = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        speculative = env.get("SPECULATIVE") == "1"
        exec_id = tid + "#1" if speculative else tid
        attempt = int(ctx.shared.get("attempt", 1))
        if not speculative and not ctx.rendezvous(timeout=30):
            return 3
        for step in range(12):
            if ctx.cancel.is_set():
                return 143
            ctx.step(exec_id, attempt, step)
            time.sleep(0.01)
        return 0

    result = TonYClient(YarnLikeBackend(rm, speculation=policy)).run_and_wait(
        job, gang_program, timeout=60)
    a = result.attempts[0]

    print(f"\n=== Act 2: speculative execution (seed={CHAOS_SEED}) ===")
    print("straggler detected:", a.stragglers)
    launched = events.of_kind("speculative_launched")[0].payload
    print(f"backup {launched['exec_id']} launched on {launched['node']} "
          f"(avoiding slow {launched['avoided_node']})")
    assert result.succeeded and len(result.attempts) == 1
    assert a.speculation == {"worker:1": "won"}
    assert a.exit_statuses["worker:1"] == EXIT_SPECULATION_LOST
    assert a.nodes["worker:1#1"] != a.nodes["worker:1"]
    print("race outcome:", a.speculation,
          f"(loser torn down with exit {EXIT_SPECULATION_LOST})")
    # losing a race is not a node failure: no strikes, no blacklist
    assert rm.health.snapshot()["failures"] == {}
    assert result.diagnostics == {}
    print("node strikes after the race:", rm.health.snapshot()["failures"])
    advice = [s.message for s in MetricsAnalyzer().analyze(job, result)
              if s.kind == "straggler"]
    print("analyzer advice:", advice[0])
    print("speculation timeline:",
          [e.kind for e in events.failure_timeline()])
    print("OK (act 2)")


def elastic_act() -> None:
    """Act 3: blacklist-forced shrink, then regrow after parole."""
    clock = [0.0]
    events = EventLog()
    health = NodeHealthTracker(threshold=1, parole_s=5.0,
                               clock=lambda: clock[0], events=events)
    # a kill on attempt 1 forces the retry that gets to regrow
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.KILL_TASK, task="worker:0", attempt=1, at_step=3))
    # 4 one-slot GPU nodes; one strike blacklists gpu-node-0 -> 3 slots left
    rm = make_cluster(num_gpu_nodes=4, num_cpu_nodes=0, gpus_per_node=1,
                      memory_mb=2048, vcores=4, event_log=events,
                      chaos=FaultInjector(plan, events=events), health=health)
    health.record_failure("gpu-node-0", TaskDiagnostics(
        task_id="worker:0", exit_status=137,
        classification=FailureClass.INFRA, message="flaky GPU (pre-struck)"))

    job = job_spec_from_props({
        "tony.application.name": "elastic-demo",
        "tony.application.max-attempts": "3",
        "tony.worker.instances": "4",
        "tony.worker.min-instances": "2",
        "tony.worker.memory": "1024",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })

    def gang_program(env, ctx):
        tid = f"{env['TASK_TYPE']}:{env['TASK_INDEX']}"
        attempt = int(ctx.shared.get("attempt", 1))
        if not ctx.rendezvous(timeout=30, exec_id=tid, attempt=attempt):
            return 3
        if tid == "worker:0":
            try:
                for step in range(int(ctx.shared.get("resume_step", 0)), 8):
                    if ctx.cancel.is_set():
                        return 143
                    ctx.step(tid, attempt, step)
                    time.sleep(0.005)
                    if (step + 1) % 2 == 0:
                        ctx.shared["ckpt_step"] = step + 1
            finally:
                ctx.shared["done"] = True
        else:
            while not ctx.cancel.is_set() and not ctx.shared.get("done"):
                time.sleep(0.002)
        ctx.rendezvous(timeout=5, exec_id=tid, attempt=attempt)
        return 0

    app_id = rm.submit_application(job.name, job.queue)
    am = ApplicationMaster(
        rm, app_id, job, gang_program,
        # the retry backoff "sleeps" past the bad node's parole deadline
        retry_policy=RetryPolicy(max_attempts=3).with_clock(
            lambda s: clock.__setitem__(0, clock[0] + 10.0)))
    am.NEGOTIATION_TIMEOUT_S = 0.4
    result = am.run()

    print(f"\n=== Act 3: elastic shrink and regrow (seed={CHAOS_SEED}) ===")
    shrink = events.of_kind("gang_resized")[0].payload
    print(f"negotiation shortfall: worker {shrink['from_count']} -> "
          f"{shrink['to_count']} (floor {shrink['floor']})")
    assert result.succeeded and len(result.attempts) == 2
    assert result.attempts[0].degraded and not result.attempts[1].degraded
    assert result.resized_attempts == {1: {"worker": 3}}
    print("attempt 1 launched degraded:", result.attempts[0].task_counts,
          "of", result.attempts[0].target_counts)
    assert events.count("attempt_degraded") == 1
    assert events.count("node_paroled") == 1
    regrow = events.of_kind("gang_regrown")[0].payload
    print(f"after parole, attempt 2 regrew: world {regrow['from_world']} -> "
          f"{regrow['world_size']}")
    assert result.attempts[1].task_counts == {"worker": 4}
    # checkpoint recovery rode along: attempt 2 resumed, not cold-started
    assert result.attempts[1].resume_step == 2
    assert not rm.live_containers() and rm.invariants_ok()

    history = JobHistoryServer()
    history.record(job, result)
    summary = history.summary(result.app_id)
    assert summary["resized_attempts"] == {1: {"worker": 3}}
    advice = [s.message for s in MetricsAnalyzer().analyze(job, result)
              if s.kind == "elastic_degraded"]
    print("analyzer advice:", advice[0])
    print("elastic timeline kinds:",
          [e.kind for e in events.failure_timeline()
           if e.kind in ("gang_resized", "attempt_degraded", "gang_regrown",
                         "node_paroled", "partial_allocation")])
    print("OK (act 3)")


def main() -> None:
    # one seeded fault plan: OOM the chief at step 5, twice (attempts 1+2)
    plan = FaultPlan(seed=CHAOS_SEED).add(
        FaultSpec(FaultKind.OOM, task="worker:0", at_step=5, count=2))
    events = EventLog()
    health = NodeHealthTracker(threshold=2, parole_s=600.0, events=events)
    rm = make_cluster(event_log=events,
                      chaos=FaultInjector(plan, events=events),
                      health=health)
    client = TonYClient(YarnLikeBackend(rm))
    cfg = get_config("tony-paper-mlp").replace(d_model=128, num_heads=2,
                                               num_kv_heads=2, d_ff=256,
                                               vocab_size=512)
    job = job_spec_from_props({
        "tony.application.name": "fault-demo",
        "tony.application.max-attempts": "3",
        "tony.worker.instances": "2",
        "tony.worker.memory": "4096",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })

    trace = []
    program = make_train_program(
        cfg, steps=12, batch_size=8, seq_len=32,
        ckpt_dir=tempfile.mkdtemp(prefix="fault-demo-"), ckpt_every=3,
        on_step=lambda s, m: trace.append((s, round(m["loss"], 3))))

    result = client.run_and_wait(job, program)

    print(f"chaos plan (seed={CHAOS_SEED}):",
          [f"{s.kind} {s.task}@step{s.at_step} x{s.count}" for s in plan.faults])
    print("attempts:", len(result.attempts))

    # the diagnostics subsystem attributed both OOMs before retrying
    for a in (1, 2):
        diag = result.diagnostics[f"a{a}/worker:0"]
        print(f"attempt {a} diagnosis: {diag.describe()}")
        assert diag.classification is FailureClass.INFRA and diag.oom
    retry_ev = events.of_kind("retry_scheduled")[0]
    print(f"retry scheduled with backoff_s={retry_ev.payload['backoff_s']}")

    # checkpoint-aware recovery: both relaunches resumed from step 3
    print("resumed attempts (attempt -> resume_step):",
          dict(result.resumed_attempts))
    assert result.resumed_attempts == {2: 3, 3: 3}
    assert events.count("attempt_resumed") == 2
    steps = [s for s, _ in trace]
    resume = next(s for i, s in enumerate(steps[1:], 1) if s <= steps[i - 1])
    print(f"training resumed from checkpoint at step {resume} (not step 0)")
    assert resume == 3

    # node blacklisting: two OOMs on one host tipped it out of placement
    bad = result.attempts[0].nodes["worker:0"]
    bl = events.of_kind("node_blacklisted")
    assert len(bl) == 1 and bl[0].payload["node"] == bad
    assert result.attempts[1].nodes["worker:0"] == bad       # struck twice
    assert bad not in result.attempts[2].nodes.values()      # then avoided
    assert result.blacklisted_nodes == [bad]
    print(f"node {bad} blacklisted after 2 OOMs; attempt 3 placed on",
          result.attempts[2].nodes["worker:0"])

    assert result.succeeded and len(result.attempts) == 3
    print("loss trace around the failures:",
          [t for t in trace if 3 <= t[0] <= 6])

    # the history server surfaces the whole recovery story in one place
    history = JobHistoryServer()
    history.record(job, result)
    summary = history.summary(result.app_id)
    assert summary["blacklisted_nodes"] == [bad]
    assert summary["resumed_attempts"] == {2: 3, 3: 3}
    print("failure timeline kinds:",
          [e.kind for e in events.failure_timeline()])
    print("OK (act 1)")

    speculation_act()
    elastic_act()


if __name__ == "__main__":
    main()
