"""Batched serving through the TonY path: an inference job with batched
autoregressive decoding (KV cache) on a reduced qwen3-family model.

    PYTHONPATH=src python examples/serve_batch.py
"""
import json

from repro.configs import get_smoke_config
from repro.core import TonYClient, YarnLikeBackend, job_spec_from_props, make_cluster
from repro.launch.serve import make_serve_program


def main() -> None:
    cfg = get_smoke_config("qwen3-1.7b")
    rm = make_cluster(num_gpu_nodes=2, num_cpu_nodes=1, gpus_per_node=4)
    client = TonYClient(YarnLikeBackend(rm))
    job = job_spec_from_props({
        "tony.application.name": "serve-batch",
        "tony.worker.instances": "2",
        "tony.worker.memory": "8192",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
    })
    box = {}
    result = client.run_and_wait(
        job, make_serve_program(cfg, batch=4, prompt_len=8, gen_len=16,
                                cache_len=24, out_box=box))
    print("status:", result.final_status)
    print("stats :", json.dumps(box["stats"], indent=2))
    print("batch of generations (first 8 tokens each):")
    for i, row in enumerate(box["gen"][:, :8].tolist()):
        print(f"  seq{i}: {row}")
    assert result.succeeded and box["gen"].shape == (4, 16)
    print("OK")


if __name__ == "__main__":
    main()
