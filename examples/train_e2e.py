"""End-to-end training driver (deliverable b): trains a ~20M-parameter
qwen3-family model for a few hundred steps through the FULL TonY
orchestration path and verifies the loss decreases. (A ~100M model at a few
hundred steps exceeds this CPU container's budget — DESIGN.md §8.5 — but the
same config scales by flag: --d-model 768 --layers 12 gives ~100M.)

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.core import TonYClient, YarnLikeBackend, job_spec_from_props, make_cluster
from repro.launch.programs import make_train_program


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").replace(
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(2, args.d_model // 64), num_kv_heads=2, head_dim=64,
        d_ff=args.d_model * 4, vocab_size=8192, dtype="float32",
        compute_param_dtype="float32", remat=False)
    print(f"model: qwen3-family reduced, {cfg.param_count()/1e6:.1f}M params")

    rm = make_cluster()
    client = TonYClient(YarnLikeBackend(rm))
    job = job_spec_from_props({
        "tony.application.name": "train-e2e",
        "tony.worker.instances": "2",
        "tony.worker.memory": "16384",
        "tony.worker.gpus": "1",
        "tony.worker.node-label": "gpu",
        "tony.ps.instances": "1",
        "tony.ps.memory": "8192",
        "tony.ps.node-label": "highmem",
    })
    losses = []
    prog = make_train_program(
        cfg, steps=args.steps, batch_size=args.batch_size,
        seq_len=args.seq_len, ckpt_dir=tempfile.mkdtemp(prefix="e2e-"),
        ckpt_every=50, lr=3e-3,
        on_step=lambda s, m: (losses.append(m["loss"]),
                              print(f"  step {s:4d} loss {m['loss']:.4f}")
                              if s % 25 == 0 else None))
    result = client.run_and_wait(job, prog)
    print("status:", result.final_status)
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps")
    assert result.succeeded
    assert losses[-1] < losses[0] - 1.0, "loss must drop substantially"
    print("OK")


if __name__ == "__main__":
    main()
